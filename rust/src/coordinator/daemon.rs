//! The daemon core: the scheduler as a long-running, thread-safe service.
//!
//! Virtual time advances against the wall clock via a **pacer** thread: every
//! tick it runs the scheduler's event loop up to `elapsed_wall × speedup`.
//! Interactive jobs' virtual scheduling latencies (the paper's metric) are
//! harvested from the event log into the daemon metrics.
//!
//! Requests split into two paths:
//!
//! * **Write path** (`SUBMIT` / `SCANCEL` / pacing) — takes the scheduler
//!   mutex, mutates, then publishes an immutable [`SchedSnapshot`] behind an
//!   `Arc` swap before releasing it.
//! * **Read path** (`SQUEUE` / `SJOB` / `STATS` / `UTIL`) — clones the
//!   published snapshot `Arc` and never touches the scheduler mutex, so
//!   status queries from thousands of clients cannot serialize behind a
//!   dispatch burst. [`super::metrics::DaemonMetrics`] counts both paths
//!   and histograms the write-lock hold time so a regression is observable.
//!
//! `WAIT` is subscription-based: a request that cannot complete immediately
//! becomes a [`WaitTicket`] parked on the [`WaitHub`] completion generation.
//! In-process callers block on the hub; the TCP server instead detaches the
//! whole connection (see [`super::server`]) — on Linux it stays registered
//! with the epoll reactor, which the hub wakes through an eventfd
//! ([`Daemon::subscribe_completions`]); elsewhere it moves into a waiter
//! registry swept by a notifier thread. Either way, hundreds of concurrent
//! `WAIT`s ride on a handful of worker threads.
//!
//! The daemon works entirely in the typed protocol: [`Daemon::handle`] is
//! `fn(&self, Request) -> Response`; wire rendering lives in
//! [`super::codec`] and is reached through [`Daemon::handle_line_versioned`].

use super::api::{
    ApiError, ContentionStats, ErrorCode, HealthReport, HealthState, JobDetail, JobSummary,
    JournalStats, ProtocolVersion, Request, Response, ResumeEntry, ResumeInfo, ResumeTarget,
    ShardKind, ShardStats, ShardUtil, SqueueFilter, StatsSnapshot, SubmitAck, SubmitSpec,
    UserScaleStats, UtilSnapshot, WaitResult,
};
use super::codec;
use super::journal::{
    self, AdmitEntry, AdmitRun, AllocLease, AllocLog, CheckpointJob, CheckpointState,
    DurabilityConfig, FsyncPolicy, Journal, JournalError, JournalRecord,
};
use super::manifest::{
    ChunkAssembler, ChunkOutcome, EntryAck, EntryReject, Manifest, ManifestAck, ManifestEntry,
    ManifestRegistry, ManifestSpan, MAX_CHUNKED_MANIFEST_ENTRIES, MAX_MANIFEST_ENTRIES,
};
use super::metrics::DaemonMetrics;
use super::recovery::{rebuild, rebuild_sharded, RecoveryError, RecoveryReport};
use super::shards::{shard_plan, SchedShards};
use super::snapshot::{wait_view_of, JobView, SchedSnapshot, WaitHub, WaitView};
use crate::cluster::Cluster;
use crate::job::{JobId, JobSpec, JobState, QosClass, UserId};
use crate::sched::{LogKind, Scheduler, SchedulerConfig};
use crate::sim::SimTime;
use crate::util::fxhash::FxHashMap;
use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Upper bound on jobs created by one batched `SUBMIT` (keeps a typo'd
/// `count=` from allocating unbounded scheduler state in one RPC).
pub const MAX_BATCH_JOBS: u64 = 1_000_000;

/// Upper bound on a `WAIT` timeout (wall seconds).
pub const MAX_WAIT_SECS: f64 = 3600.0;

/// How long a parked in-process `WAIT` sleeps between self-pace polls when
/// no completion notify arrives (the hub wakes it earlier on progress).
const WAIT_POLL: Duration = Duration::from_millis(2);

/// Daemon parameters.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Virtual seconds advanced per wall-clock second (the simulation keeps
    /// up with real submissions at any speedup; 1.0 = real time).
    pub speedup: f64,
    /// Pacer tick in milliseconds.
    pub pacer_tick_ms: u64,
    /// Grace period (virtual seconds) a terminal job stays in the
    /// published table before it is retired into the history side-table.
    /// Bounds snapshot publish cost for long-lived daemons: `SQUEUE` stops
    /// listing retired jobs, `SJOB` still answers from history. `None`
    /// never retires.
    pub retire_grace_secs: Option<f64>,
    /// Cap on the retired-job history side-table. Retirement bounds the
    /// *published* table; this bounds the daemon's total memory: past the
    /// cap the oldest retired records are pruned (their event-log entries
    /// went with retirement), and `SJOB`/`WAIT` on a pruned id return the
    /// usual typed `not_found`. `None` keeps history forever.
    pub history_cap: Option<usize>,
    /// Write-ahead journal configuration. `Some` makes every admission and
    /// cancel durable *before* it is acknowledged (see `PROTOCOL.md`
    /// §Durability); `None` keeps the daemon fully in-memory (the seed
    /// behavior).
    pub durability: Option<DurabilityConfig>,
    /// Scheduler shard count. `1` (the default) is exactly the unsharded
    /// daemon: one scheduler mutex over the whole cluster. `> 1` splits the
    /// back end into one scheduler per partition over disjoint node slices
    /// (see [`SchedShards`]); the count is clamped to the layout's
    /// partition count and falls back to `1` when the cluster or layout
    /// cannot shard. Composes with `durability`: a sharded daemon keeps
    /// one journal per shard under its own mutex, plus the allocator log
    /// that makes recovered ids globally deterministic (see `PROTOCOL.md`
    /// §Durability).
    pub shard_count: usize,
    /// Overload control plane: admission rate limits, the global
    /// inflight budget, and health-probe tuning (see [`OverloadConfig`]).
    /// The default disables every limit, so existing deployments see no
    /// behavior change until they opt in.
    pub overload: OverloadConfig,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            speedup: 60.0,
            pacer_tick_ms: 5,
            retire_grace_secs: Some(3600.0),
            history_cap: Some(100_000),
            durability: None,
            shard_count: 1,
            overload: OverloadConfig::default(),
        }
    }
}

/// Overload-control parameters (see `PROTOCOL.md` §Overload & health).
/// Rate limits and the inflight budget apply only to *sheddable* work —
/// new `SUBMIT`/`MSUBMIT` admissions. Reads (`SQUEUE`/`SJOB`/`STATS`) and
/// `WAIT` are never shed: they serve off snapshots and cost no scheduler
/// lock, so refusing them would save nothing.
#[derive(Debug, Clone)]
pub struct OverloadConfig {
    /// Per-connection request-line rate (lines/second, enforced by the
    /// transport before the line reaches a worker). `0.0` disables.
    pub conn_rate: f64,
    /// Per-connection burst allowance (bucket capacity, lines).
    pub conn_burst: f64,
    /// Per-user sheddable-request rate (submissions/second, keyed on the
    /// submitting user id). `0.0` disables.
    pub user_rate: f64,
    /// Per-user burst allowance (bucket capacity, requests).
    pub user_burst: f64,
    /// Global cap on concurrently *executing* sheddable requests. An
    /// admission arriving with the gauge at the cap is refused with a
    /// typed `overloaded` before any scheduler lock. `0` = unlimited.
    pub inflight_budget: u64,
    /// Write-lock hold p99 (nanoseconds) above which the health probe
    /// reports `Shedding`. `0` disables the signal.
    pub lock_p99_shed_ns: u64,
    /// Health-probe cadence (milliseconds). The probe rides the pacer
    /// tick and the request path, throttled to this interval.
    pub probe_interval_ms: u64,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        Self {
            conn_rate: 0.0,
            conn_burst: 0.0,
            user_rate: 0.0,
            user_burst: 0.0,
            inflight_budget: 0,
            lock_p99_shed_ns: 0,
            probe_interval_ms: 100,
        }
    }
}

/// Retry hint handed to clients refused by the inflight budget: long
/// enough to drain a burst, short enough that a polite retry loop still
/// feels interactive.
const SHED_RETRY_MS: u64 = 50;

/// Per-user admission-bucket map size that arms the first idle-bucket
/// sweep. Below this the map is too small to be worth scanning.
const USER_BUCKET_SWEEP_MIN: usize = 8_192;

/// Hard cap on live per-user admission buckets. A sweep that cannot get
/// under it by retiring refill-saturated buckets (a coordinated burst
/// wider than the cap inside one refill window) evicts the least-recently
/// touched buckets down to half the cap — those users simply get a fresh,
/// full bucket on their next submission, an error toward admitting only.
const USER_BUCKET_HARD_CAP: usize = 131_072;

/// A standard token bucket over wall-clock time (std-only: refill is
/// computed lazily from the elapsed interval, no timer thread). Used for
/// the per-user admission limit here and the per-connection line limit in
/// the transports.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    capacity: f64,
    rate: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// A bucket refilling at `rate` tokens/second with `burst` capacity
    /// (clamped to at least one token so a positive rate can ever admit),
    /// starting full.
    pub fn new(rate: f64, burst: f64, now: Instant) -> Self {
        let capacity = burst.max(1.0);
        Self {
            capacity,
            rate: rate.max(0.0),
            tokens: capacity,
            last: now,
        }
    }

    /// Take one token, or report how many milliseconds until one will be
    /// available (the `retry_after_ms` hint; at least 1).
    pub fn try_take(&mut self, now: Instant) -> Result<(), u64> {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.capacity);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            return Ok(());
        }
        let need = 1.0 - self.tokens;
        let ms = if self.rate > 0.0 {
            (need / self.rate * 1000.0).ceil() as u64
        } else {
            // A zero-rate bucket never refills: the hint is "much later".
            60_000
        };
        Err(ms.max(1))
    }

    /// Would a refill at `now` fill the bucket back to capacity? A
    /// saturated bucket is state-identical to the fresh bucket
    /// [`TokenBucket::new`] hands out (buckets start full), so the owner
    /// can drop it without changing any future admission decision. Pure
    /// projection — the bucket is not mutated.
    pub fn is_saturated(&self, now: Instant) -> bool {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens + dt * self.rate >= self.capacity
    }
}

/// RAII decrement for the sheddable-inflight gauge: admission increments,
/// drop (after the request executes, parks, or errors) decrements.
struct InflightGuard<'a>(Option<&'a AtomicU64>);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        if let Some(gauge) = self.0 {
            gauge.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// A boot configuration the daemon refuses, typed — the CLI prints it and
/// exits nonzero instead of unwinding with a panic backtrace
/// ([`Daemon::try_new`] / [`Daemon::recover`]).
#[derive(Debug)]
pub enum ConfigError {
    /// A fresh boot (`Daemon::new`) pointed at a journal directory that
    /// already holds journal state — recover it instead of silently
    /// shadowing it.
    JournalExists(PathBuf),
    /// The journal directory could not be created or written at boot.
    JournalIo(PathBuf, String),
    /// The on-disk journal layout does not match the boot configuration:
    /// flat segments with `--sched-shards > 1`, a sharded layout with a
    /// single-shard boot, or a shard-directory set that does not match
    /// the shard plan.
    ShardLayoutMismatch {
        /// Journal root directory.
        dir: PathBuf,
        /// What specifically mismatched.
        detail: String,
    },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::JournalExists(dir) => write!(
                f,
                "journal directory {} already holds journal state; \
                 recover it (or point --journal elsewhere)",
                dir.display()
            ),
            ConfigError::JournalIo(dir, e) => {
                write!(f, "journal directory {} is unusable: {e}", dir.display())
            }
            ConfigError::ShardLayoutMismatch { dir, detail } => write!(
                f,
                "journal layout at {} does not match the boot config: {detail}",
                dir.display()
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

/// A blocked `WAIT`, waiting for its jobs' completion events.
#[derive(Debug, Clone)]
pub struct WaitTicket {
    /// Job ids the client asked about.
    pub jobs: Vec<u64>,
    /// Wall deadline.
    pub deadline: Instant,
    /// When the request arrived (metrics).
    pub started: Instant,
}

/// Outcome of admitting a `WAIT`: either an immediate response or a parked
/// ticket to poll on completion notifies.
pub enum WaitStart {
    /// Settled (or rejected) without blocking.
    Done(Response),
    /// Parked: poll [`Daemon::poll_wait`] after each completion notify.
    Parked(WaitTicket),
}

/// A parked `WAIT` plus the protocol version its eventual response renders
/// in (what the server's waiter registry holds per connection).
pub struct ParkedWait {
    /// The parked wait.
    pub ticket: WaitTicket,
    /// Render version for the deferred response.
    pub version: ProtocolVersion,
}

/// Outcome of one request line when the caller cannot block (the server's
/// connection loop).
pub enum LineOutcome {
    /// Rendered response and, after a successful `HELLO`, the version the
    /// connection speaks from the next request on.
    Done(String, Option<ProtocolVersion>),
    /// A `WAIT` parked; respond later via [`Daemon::poll_wait`] +
    /// [`Daemon::finish_wait`].
    Parked(ParkedWait),
}

/// The daemon: scheduler write path + published read snapshot + WAIT hub.
pub struct Daemon {
    /// The scheduler back end: one shard (the unsharded daemon) or one per
    /// partition ([`DaemonConfig::shard_count`]). Each shard has its own
    /// mutex; the read path below never takes any of them.
    shards: SchedShards,
    /// The published read view (see [`SchedSnapshot`]). Swapped, never
    /// mutated: readers clone the `Arc` under a momentary read lock. In
    /// sharded mode this holds the epoch-stamped merge of the per-shard
    /// snapshot slots.
    snapshot: RwLock<Arc<SchedSnapshot>>,
    hub: WaitHub,
    /// Daemon metrics (public for the e2e driver's reporting).
    pub metrics: DaemonMetrics,
    running: AtomicBool,
    start: Instant,
    /// Virtual time at daemon start (non-zero after recovery: the pacer
    /// resumes from the recovered instant, it never rewinds).
    virtual_base: SimTime,
    cfg: DaemonConfig,
    /// The durable store, when durability is on: one journal per
    /// scheduler shard (each locked strictly *inside* its shard's
    /// scheduler mutex), the allocator log in sharded mode, and the
    /// group-commit parking lot.
    journal: Option<DurableStore>,
    /// Registered manifests (RESUME / per-entry WAIT lookups). Written on
    /// admission under the scheduler mutex; read lock-free of it.
    manifests: RwLock<ManifestRegistry>,
    tracked: Mutex<BTreeSet<JobId>>,
    /// Retired terminal jobs: frozen views written once at retirement (the
    /// write path, amortized O(1) per job over its lifetime) and read by
    /// `SJOB`/`WAIT` after the job left the published table. Never takes
    /// the scheduler mutex on the read side. Bounded by
    /// [`DaemonConfig::history_cap`]: the oldest retirements are pruned
    /// first (ids retire in end-time order, so eviction follows insertion).
    history: RwLock<HistoryTable>,
    /// Per-user admission token buckets ([`OverloadConfig::user_rate`]).
    /// Touched only on the sheddable write path, before any scheduler
    /// lock; the read path never sees it. Bounded: idle (refill-saturated)
    /// buckets are retired by a watermark-armed sweep so a million distinct
    /// users cannot grow the map without bound (see
    /// [`USER_BUCKET_HARD_CAP`]).
    user_buckets: Mutex<FxHashMap<u32, TokenBucket>>,
    /// Bucket-map size that arms the next idle-bucket sweep (GC-style
    /// watermark: reset to twice the post-sweep size, so the O(n) retain
    /// amortizes to O(1) per admission).
    user_bucket_sweep_at: AtomicU64,
    /// Concurrently executing sheddable requests (the inflight-budget
    /// gauge; see [`InflightGuard`]).
    inflight: AtomicU64,
    /// Encoded [`HealthState`]: 0 healthy, 1 shedding, 2 read-only.
    /// `ReadOnly` is sticky — a poisoned journal never un-poisons.
    health: AtomicU64,
    /// Milliseconds since `start` at the last health *transition*
    /// (`HEALTH`'s `since_secs`).
    health_since_ms: AtomicU64,
    /// Milliseconds since `start` at the last health probe (throttle).
    last_probe_ms: AtomicU64,
    /// Shed events since the last probe: the probe drains this to decide
    /// `Healthy` vs `Shedding`, so the state recovers within one probe
    /// interval of the pressure stopping.
    sheds_since_probe: AtomicU64,
}

/// The bounded retired-job side-table: id → frozen view, plus the
/// insertion-order queue the cap evicts from.
#[derive(Default)]
struct HistoryTable {
    views: FxHashMap<u64, Arc<JobView>>,
    order: std::collections::VecDeque<u64>,
}

impl HistoryTable {
    fn get(&self, id: &u64) -> Option<&Arc<JobView>> {
        self.views.get(id)
    }

    fn contains_key(&self, id: &u64) -> bool {
        self.views.contains_key(id)
    }

    /// Insert a retired view, evicting the oldest records past `cap`.
    fn insert_capped(&mut self, id: u64, view: Arc<JobView>, cap: Option<usize>) {
        if self.views.insert(id, view).is_none() {
            self.order.push_back(id);
        }
        if let Some(cap) = cap {
            while self.views.len() > cap.max(1) {
                let Some(oldest) = self.order.pop_front() else { break };
                self.views.remove(&oldest);
            }
        }
    }

    /// Clone the views in insertion (retirement) order — checkpoint
    /// capture, so a recovered daemon rebuilds the same eviction order.
    fn ordered_views(&self) -> Vec<JobView> {
        self.order
            .iter()
            .filter_map(|id| self.views.get(id).map(|v| (**v).clone()))
            .collect()
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.views.len()
    }
}

/// The durable half of a (possibly sharded) daemon.
struct DurableStore {
    /// One slot per scheduler shard, index-aligned with [`SchedShards`].
    /// A single-shard daemon has exactly one slot (the flat layout).
    slots: Vec<JournalSlot>,
    /// The id-allocator log (sharded mode only): every reserved id range
    /// is leased here, fsync'd per policy, *before* any part of the
    /// admission lands in a shard journal — recovery's id watermark.
    alloc: Option<Mutex<AllocLog>>,
    /// Lease sequence: the last lease number issued (0 = none yet).
    lease_seq: AtomicU64,
    /// Checkpoint capture sequence: orders per-shard checkpoints by
    /// registry freshness (see [`CheckpointState::global_seq`]). Taken
    /// under the manifest-registry read lock so a higher seq always
    /// carries a superset registry.
    global_seq: AtomicU64,
    /// Batch concurrent `fsync = always` acks into shared syncs (the
    /// parked-writer group commit).
    group_commit: bool,
}

impl DurableStore {
    fn new(
        journals: Vec<JournalSlot>,
        alloc: Option<AllocLog>,
        dcfg: &DurabilityConfig,
        lease_seq: u64,
        global_seq: u64,
    ) -> Self {
        Self {
            slots: journals,
            alloc: alloc.map(Mutex::new),
            lease_seq: AtomicU64::new(lease_seq),
            global_seq: AtomicU64::new(global_seq),
            group_commit: dcfg.group_commit && dcfg.fsync == FsyncPolicy::Always,
        }
    }
}

/// One scheduler shard's journal plus its group-commit state.
struct JournalSlot {
    /// The shard's write-ahead journal. Locked inside the shard's
    /// scheduler mutex on the append path; the group-commit leader locks
    /// it *without* the scheduler mutex (sync only, no appends).
    journal: Mutex<Journal>,
    /// Highest lease this shard has applied (updated under the shard's
    /// scheduler mutex after the mutation; read at checkpoint capture so
    /// `applied_lease >= L` certifies the checkpoint absorbed lease L).
    applied_lease: AtomicU64,
    /// Group-commit parking lot (meaningful when
    /// [`DurableStore::group_commit`] is on).
    gc: GroupCommit,
}

impl JournalSlot {
    fn new(journal: Journal, applied_lease: u64) -> Self {
        Self {
            journal: Mutex::new(journal),
            applied_lease: AtomicU64::new(applied_lease),
            gc: GroupCommit::default(),
        }
    }
}

/// The parked-writer protocol: concurrent `fsync = always` admissions
/// append deferred (under the shard+journal locks), then park here until
/// some writer — the elected leader — performs ONE fsync that covers every
/// parked record. Writers whose record an earlier sync already covered
/// return without ever syncing; the rest elect exactly one leader at a
/// time and the others wait on the condvar (with a short self-promotion
/// timeout so a record can never be stranded un-synced).
#[derive(Default)]
struct GroupCommit {
    state: Mutex<GcState>,
    cv: Condvar,
}

/// Shared group-commit state, under [`GroupCommit::state`].
#[derive(Default)]
struct GcState {
    /// Mirror of the journal's synced append sequence, updated by each
    /// leader (may lag the journal after a checkpoint rotation syncs
    /// everything — the next leader's no-op sync refreshes it).
    synced: u64,
    /// A leader is currently inside the fsync.
    leader: bool,
    /// A group sync failed: the journal is poisoned and every parked
    /// writer (and every later one) fails its ack.
    poisoned: bool,
}

/// How long a parked group-commit writer waits for the leader before
/// self-promoting (a liveness backstop, not the batching window — the
/// leader syncs immediately and batching comes from appends landing while
/// an fsync is in flight).
const GROUP_COMMIT_PARK: Duration = Duration::from_millis(2);

impl Daemon {
    /// Create a daemon over a fresh scheduler, panicking on an invalid
    /// boot configuration — a daemon that silently dropped its durability
    /// guarantee would be worse than one that failed to boot. The CLI
    /// uses [`Daemon::try_new`] for a typed refusal instead.
    pub fn new(cluster: Cluster, sched_cfg: SchedulerConfig, cfg: DaemonConfig) -> Arc<Self> {
        Self::try_new(cluster, sched_cfg, cfg)
            .unwrap_or_else(|e| panic!("creating the write-ahead journal: {e}"))
    }

    /// Create a daemon over a fresh scheduler, returning a typed
    /// [`ConfigError`] when the boot configuration is invalid (journal
    /// directory already holds state, or cannot be created/written). When
    /// durability is configured this creates a fresh journal per
    /// scheduler shard — plus the allocator log in sharded mode (use
    /// [`Daemon::recover`] on a non-empty journal directory).
    pub fn try_new(
        cluster: Cluster,
        sched_cfg: SchedulerConfig,
        cfg: DaemonConfig,
    ) -> Result<Arc<Self>, ConfigError> {
        let shards = if cfg.shard_count > 1 {
            SchedShards::sharded(cluster, sched_cfg, cfg.shard_count)
        } else {
            SchedShards::single(cluster, sched_cfg)
        };
        let journal = match &cfg.durability {
            Some(d) => Some(Self::create_store(d, shards.count())?),
            None => None,
        };
        Ok(Self::assemble(
            shards,
            cfg,
            journal,
            ManifestRegistry::new(),
            Vec::new(),
        ))
    }

    /// Build the durable store for a fresh boot: the flat single-shard
    /// journal, or (sharded) the allocator log plus one journal per
    /// shard. Refuses typed when the directory already holds journal
    /// state in either layout, or cannot be written.
    fn create_store(d: &DurabilityConfig, nshards: usize) -> Result<DurableStore, ConfigError> {
        let io = |e: JournalError| match e {
            JournalError::NotEmpty(p) => ConfigError::JournalExists(p),
            other => ConfigError::JournalIo(d.dir.clone(), other.to_string()),
        };
        if journal::dir_has_segments(&d.dir) {
            return Err(ConfigError::JournalExists(d.dir.clone()));
        }
        if nshards > 1 {
            let alloc = AllocLog::create(d).map_err(io)?;
            let mut slots = Vec::with_capacity(nshards);
            for idx in 0..nshards {
                let j = Journal::create(&d.for_shard(idx)).map_err(io)?;
                slots.push(JournalSlot::new(j, 0));
            }
            Ok(DurableStore::new(slots, Some(alloc), d, 0, 0))
        } else {
            let j = Journal::create(d).map_err(io)?;
            Ok(DurableStore::new(vec![JournalSlot::new(j, 0)], None, d, 0, 0))
        }
    }

    /// Recover a daemon from an existing journal: replay the newest
    /// checkpoint plus the tail into a fresh scheduler over
    /// `cluster`/`sched_cfg` (which must match the crashed daemon's), then
    /// resume journaling on the same directory. Running/suspended jobs are
    /// re-queued; interactive jobs that had not yet dispatched are
    /// re-tracked so the latency harvest (and parked-`WAIT` resolution)
    /// picks them up exactly once.
    pub fn recover(
        cluster: Cluster,
        sched_cfg: SchedulerConfig,
        cfg: DaemonConfig,
    ) -> Result<(Arc<Self>, RecoveryReport), RecoveryError> {
        let dcfg = cfg
            .durability
            .as_ref()
            .ok_or_else(|| RecoveryError::Mismatch("recover() without durability config".into()))?
            .clone();
        if journal::dir_has_shard_layout(&dcfg.dir) {
            return Self::recover_sharded(cluster, sched_cfg, cfg, &dcfg);
        }
        // Flat (single-shard) layout — refuse a sharded boot over it
        // rather than replaying one shard's contract into many.
        if cfg.shard_count > 1 && shard_plan(&cluster, &sched_cfg, cfg.shard_count).len() > 1 {
            return Err(ConfigError::ShardLayoutMismatch {
                dir: dcfg.dir.clone(),
                detail: format!(
                    "journal is single-shard but the boot config asks for {} scheduler shards",
                    cfg.shard_count
                ),
            }
            .into());
        }
        let (journal, recovered) = Journal::recover(&dcfg)?;
        let rebuilt = rebuild(cluster, sched_cfg, &recovered)?;
        let report = rebuilt.report;
        let applied = recovered.checkpoint.applied_lease;
        let global_seq = recovered.checkpoint.global_seq;
        let store = DurableStore::new(
            vec![JournalSlot::new(journal, applied)],
            None,
            &dcfg,
            applied,
            global_seq,
        );
        let daemon = Self::assemble(
            SchedShards::single_from(rebuilt.sched),
            cfg,
            Some(store),
            rebuilt.registry,
            rebuilt.history,
        );
        daemon.compact_after_recovery();
        Ok((daemon, report))
    }

    /// Recover a sharded daemon: replay the allocator log and every
    /// shard's journal ([`rebuild_sharded`] — the lease completeness rule
    /// keeps cross-shard manifests atomic), then resume journaling on the
    /// same per-shard directories. The boot config's shard plan must
    /// match the writer's layout.
    fn recover_sharded(
        cluster: Cluster,
        sched_cfg: SchedulerConfig,
        cfg: DaemonConfig,
        dcfg: &DurabilityConfig,
    ) -> Result<(Arc<Self>, RecoveryReport), RecoveryError> {
        let plan = shard_plan(&cluster, &sched_cfg, cfg.shard_count);
        if plan.len() <= 1 {
            return Err(ConfigError::ShardLayoutMismatch {
                dir: dcfg.dir.clone(),
                detail: format!(
                    "journal is sharded but the boot config (shard_count {}) \
                     resolves to a single scheduler shard",
                    cfg.shard_count
                ),
            }
            .into());
        }
        let found: Vec<usize> = journal::list_shard_dirs(&dcfg.dir)
            .into_iter()
            .map(|(i, _)| i)
            .collect();
        if found != (0..plan.len()).collect::<Vec<_>>() {
            return Err(ConfigError::ShardLayoutMismatch {
                dir: dcfg.dir.clone(),
                detail: format!(
                    "journal shard directories {found:?} do not match the {}-shard plan",
                    plan.len()
                ),
            }
            .into());
        }
        let (alloc, _leases) = AllocLog::recover(dcfg)?;
        let mut journals = Vec::with_capacity(plan.len());
        let mut recs = Vec::with_capacity(plan.len());
        for idx in 0..plan.len() {
            let (j, rec) = Journal::recover(&dcfg.for_shard(idx))?;
            journals.push(j);
            recs.push(rec);
        }
        let rebuilt = rebuild_sharded(&plan, sched_cfg.clone(), &recs, alloc.watermark_id())?;
        // Restart the lease and checkpoint sequences past everything ever
        // issued — torn leases included: reusing a torn lease number could
        // alias an old dropped part with a new admission.
        let mut lease_seq = alloc.watermark_lease();
        let mut global_seq = 0u64;
        for rec in &recs {
            lease_seq = lease_seq.max(rec.checkpoint.applied_lease);
            global_seq = global_seq.max(rec.checkpoint.global_seq);
            for r in &rec.tail {
                if let JournalRecord::ShardAdmit { lease, .. } = r {
                    lease_seq = lease_seq.max(*lease);
                }
            }
        }
        let slots: Vec<JournalSlot> = journals
            .into_iter()
            .zip(rebuilt.applied_leases.iter())
            .map(|(j, &applied)| JournalSlot::new(j, applied))
            .collect();
        let store = DurableStore::new(slots, Some(alloc), dcfg, lease_seq, global_seq);
        let shards = SchedShards::sharded_from(
            plan.iter()
                .zip(rebuilt.scheds)
                .map(|(&(pid, label, _), sched)| (pid, label, sched))
                .collect(),
            sched_cfg.layout,
            rebuilt.next_id,
        );
        let report = rebuilt.report;
        let daemon = Self::assemble(shards, cfg, Some(store), rebuilt.registry, rebuilt.history);
        daemon.compact_after_recovery();
        Ok((daemon, report))
    }

    /// Post-recovery compaction: write a fresh checkpoint into every
    /// shard's journal (rotating the replayed segments away) and rewrite
    /// the allocator log down to its watermark record, so each restart
    /// begins from a checkpoint instead of replaying an ever-growing
    /// tail. A failure poisons that journal (the daemon degrades to
    /// read-only, same as a live checkpoint failure) but never loses
    /// recovered state — the old segments stay until rotation succeeds.
    fn compact_after_recovery(&self) {
        let Some(store) = &self.journal else {
            return;
        };
        for idx in 0..self.shards.count() {
            let sched = self.shards.lock(idx);
            let state = self.capture_checkpoint_locked(idx, &sched);
            let mut j = store.slots[idx].journal.lock().expect("journal lock poisoned");
            if j.is_poisoned() {
                continue;
            }
            if let Err(e) = j.checkpoint(&state) {
                self.note_journal_failure(&e);
                eprintln!(
                    "spotcloud: post-recovery checkpoint failed (journal now read-only): {e}"
                );
            }
        }
        if let Some(alloc) = &store.alloc {
            let mut a = alloc.lock().expect("alloc log poisoned");
            if let Err(e) = a.compact() {
                self.note_journal_failure(&e);
                eprintln!("spotcloud: allocator-log compaction failed: {e}");
            }
        }
    }

    fn assemble(
        shards: SchedShards,
        cfg: DaemonConfig,
        journal: Option<DurableStore>,
        registry: ManifestRegistry,
        history_seed: Vec<JobView>,
    ) -> Arc<Self> {
        // Re-arm the latency-harvest bookkeeping for interactive jobs that
        // were admitted but had not dispatched when the state was captured
        // (no-op on a fresh scheduler). Fresh sharded daemons start empty,
        // but the sweep stays shard-agnostic for uniformity.
        let mut virtual_base = SimTime::ZERO;
        let mut tracked = BTreeSet::new();
        for idx in 0..shards.count() {
            let sched = shards.lock(idx);
            virtual_base = virtual_base.max(sched.now());
            for job in sched.jobs() {
                if job.spec.qos == QosClass::Normal
                    && !job.state.is_terminal()
                    && sched.log().last(job.id, LogKind::DispatchDone).is_none()
                {
                    tracked.insert(job.id);
                }
            }
        }
        // Seed the history table through the same capped insert path as
        // live retirement, original order — pruning semantics after a
        // recovery match a daemon that never crashed.
        let mut history = HistoryTable::default();
        for v in history_seed {
            history.insert_capped(v.id, Arc::new(v), cfg.history_cap);
        }
        let snapshot = if shards.is_sharded() {
            shards.merged_snapshot()
        } else {
            shards.shard_snapshot(0)
        };
        Arc::new(Self {
            shards,
            snapshot: RwLock::new(snapshot),
            hub: WaitHub::default(),
            metrics: DaemonMetrics::default(),
            running: AtomicBool::new(true),
            start: Instant::now(),
            virtual_base,
            cfg,
            journal,
            manifests: RwLock::new(registry),
            tracked: Mutex::new(tracked),
            history: RwLock::new(history),
            user_buckets: Mutex::new(FxHashMap::default()),
            user_bucket_sweep_at: AtomicU64::new(USER_BUCKET_SWEEP_MIN as u64),
            inflight: AtomicU64::new(0),
            health: AtomicU64::new(0),
            health_since_ms: AtomicU64::new(0),
            last_probe_ms: AtomicU64::new(0),
            sheds_since_probe: AtomicU64::new(0),
        })
    }

    /// Still serving?
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    /// Request shutdown.
    pub fn shutdown(&self) {
        self.running.store(false, Ordering::SeqCst);
        // Parked waiters must observe the flag and fail their waits.
        self.hub.notify();
    }

    /// Target virtual time for the current wall clock (offset by the
    /// recovered instant: virtual time never rewinds across a restart).
    fn target_now(&self) -> SimTime {
        self.virtual_base + SimTime::from_secs_f64(self.start.elapsed().as_secs_f64() * self.cfg.speedup)
    }

    // ---- write path --------------------------------------------------------

    /// Run a mutating operation under shard 0's scheduler mutex (the whole
    /// scheduler in single-shard mode). Kept as the name every single-shard
    /// write site uses; sharded call sites route via
    /// [`Daemon::with_shard_mut`].
    fn with_sched_mut<T>(&self, f: impl FnOnce(&mut Scheduler) -> T) -> T {
        self.with_shard_mut(0, f)
    }

    /// Run a mutating operation under one shard's scheduler mutex, publish
    /// a fresh snapshot, and account the lock hold time. Every scheduler
    /// write goes through here (or the multi-shard `MSUBMIT` path); the
    /// read path never takes these locks.
    ///
    /// Single-shard mode publishes directly under the lock (exactly the
    /// unsharded daemon). Sharded mode stores the shard's snapshot slot
    /// under the lock, then merges and swaps the global snapshot *after*
    /// releasing it — the epoch sequence keeps racing publishes monotone.
    fn with_shard_mut<T>(&self, idx: usize, f: impl FnOnce(&mut Scheduler) -> T) -> T {
        let sharded = self.shards.is_sharded();
        let mut sched = self.shards.lock(idx);
        let t0 = Instant::now(); // hold time, not acquisition wait
        let out = f(&mut sched);
        if sharded {
            self.shards.store_snapshot(idx, &sched);
        } else {
            self.publish_locked(&sched);
        }
        let hold_ns = t0.elapsed().as_nanos() as u64;
        drop(sched);
        self.shards.record_hold(idx, hold_ns);
        self.metrics.record_write_lock(hold_ns);
        if sharded {
            self.publish_merged();
        }
        out
    }

    /// Sharded publish: merge every shard's snapshot slot into one
    /// epoch-stamped global view and swap it in if (and only if) it is
    /// newer than the published one. Called outside the shard mutexes;
    /// concurrent merges race benignly — the oldest loses the swap.
    fn publish_merged(&self) {
        let next = self.shards.merged_snapshot();
        let prev = Arc::clone(&self.snapshot.read().expect("snapshot poisoned"));
        if next.version <= prev.version {
            return;
        }
        let progressed =
            next.stats.dispatches != prev.stats.dispatches || next.ended != prev.ended;
        {
            let mut slot = self.snapshot.write().expect("snapshot poisoned");
            if next.version > slot.version {
                *slot = next;
            }
        }
        if progressed {
            self.hub.notify();
        }
    }

    /// Capture + swap the published snapshot. Must be called with the
    /// scheduler mutex held (that is what serializes publishes). Bumps the
    /// WAIT completion generation when dispatch or terminal progress landed.
    fn publish_locked(&self, sched: &Scheduler) {
        let prev = Arc::clone(&self.snapshot.read().expect("snapshot poisoned"));
        if prev.version == sched.change_version() && prev.virtual_now == sched.now() {
            return; // nothing moved, not even the clock
        }
        let next = Arc::new(SchedSnapshot::capture(sched, Some(&prev)));
        let progressed =
            next.stats.dispatches != prev.stats.dispatches || next.ended != prev.ended;
        *self.snapshot.write().expect("snapshot poisoned") = next;
        if progressed {
            self.hub.notify();
        }
    }

    /// Count a journal-layer failure into the metrics and pin the health
    /// state at `ReadOnly`: the first error on a journal is the poison
    /// transition ([`JournalError::Poisoned`] is the already-poisoned
    /// rejection, not a new transition), and a poisoned journal never
    /// un-poisons, so the state is sticky and observable (`HEALTH`,
    /// `STATS`) instead of the former silent per-request degradation.
    fn note_journal_failure(&self, e: &JournalError) {
        if !matches!(e, JournalError::Poisoned) {
            self.metrics.journal_poisoned.fetch_add(1, Ordering::Relaxed);
        }
        self.set_health(HealthState::ReadOnly);
    }

    /// Map a journal error into the typed admission failure (and count the
    /// poison transition). `read_only`, not `internal`: the client can tell
    /// "this daemon lost its journal and refuses writes" apart from a bug,
    /// and knows reads and `WAIT` still serve.
    fn journal_error(&self, e: JournalError) -> ApiError {
        self.note_journal_failure(&e);
        ApiError::new(
            ErrorCode::ReadOnly,
            format!("write-ahead journal append failed (request not acked): {e}"),
        )
    }

    /// Append one record to shard `idx`'s journal. Call with that shard's
    /// scheduler mutex held, *before* the mutation the record describes —
    /// on `Err` the caller must neither mutate nor ack, so an
    /// acknowledged action always exists on disk first. A poisoned
    /// journal fails every subsequent admission the same way: the daemon
    /// degrades to read-only rather than silently dropping durability.
    ///
    /// Under group commit (`fsync = always` with
    /// [`DurabilityConfig::group_commit`]) the append is *deferred*:
    /// `Ok(Some(seq))` means the record is written but not yet synced —
    /// the caller must [`Daemon::group_sync_wait`] on `seq` *after*
    /// releasing the scheduler mutex and before acking the client.
    /// `Ok(None)` means the append already satisfied its fsync policy.
    fn journal_append(&self, idx: usize, rec: &JournalRecord) -> Result<Option<u64>, ApiError> {
        let Some(store) = &self.journal else {
            return Ok(None);
        };
        let mut j = store.slots[idx].journal.lock().expect("journal lock poisoned");
        let out = if store.group_commit {
            j.append_deferred(rec).map(Some)
        } else {
            j.append(rec).map(|()| None)
        };
        drop(j);
        match out {
            Ok(seq) => {
                self.metrics.journal_appends.fetch_add(1, Ordering::Relaxed);
                if seq.is_none()
                    && self.cfg.durability.as_ref().map(|d| d.fsync) == Some(FsyncPolicy::Always)
                {
                    // Strict mode: this ack waited for its own fsync.
                    self.metrics.journal_synced_appends.fetch_add(1, Ordering::Relaxed);
                }
                Ok(seq)
            }
            Err(e) => Err(self.journal_error(e)),
        }
    }

    /// Lease a freshly reserved global id range in the allocator log
    /// (sharded durability only). The lease record is fsync'd per policy
    /// *before* any shard journal sees a part referencing it — recovery's
    /// id watermark can then never run behind an id that reached a shard
    /// journal. Call with the touched shard mutexes held, before any
    /// scheduler mutation: on `Err` nothing was mutated and nothing is
    /// acked (the reserved ids are burned, which is harmless — ids are
    /// unique, not dense).
    fn lease_ids(&self, first: u64, count: u64) -> Result<u64, ApiError> {
        let store = self.journal.as_ref().expect("lease without a journal");
        let alloc = store
            .alloc
            .as_ref()
            .expect("lease on a single-shard journal");
        let lease = store.lease_seq.fetch_add(1, Ordering::SeqCst) + 1;
        let mut a = alloc.lock().expect("alloc log poisoned");
        a.append(AllocLease {
            lease,
            first,
            count,
        })
        .map_err(|e| self.journal_error(e))?;
        Ok(lease)
    }

    /// Record that shard `idx` applied lease `lease` (call under that
    /// shard's scheduler mutex, after the mutation): checkpoint captures
    /// read this watermark under the same mutex, so a checkpoint claiming
    /// `applied_lease >= L` always contains lease `L`'s local effects.
    fn note_applied_lease(&self, idx: usize, lease: u64) {
        if let Some(store) = &self.journal {
            store.slots[idx].applied_lease.fetch_max(lease, Ordering::SeqCst);
        }
    }

    /// Park until shard `idx`'s journal has synced through append `seq`
    /// (the group-commit parked-writer protocol). Call *without* the
    /// scheduler mutex. Whichever parked writer finds no sync in flight
    /// becomes the leader and performs one fsync covering every record
    /// appended so far; the rest wait on the condvar and re-check (with a
    /// short self-promotion timeout as a liveness backstop). A failed
    /// group sync poisons the journal and fails every parked ack — the
    /// admission is applied-but-unacked, the same documented
    /// at-least-once class as `SCANCEL`'s mutate-then-append divergence.
    fn group_sync_wait(&self, idx: usize, seq: u64) -> Result<(), ApiError> {
        let store = self.journal.as_ref().expect("group sync without a journal");
        let slot = &store.slots[idx];
        let mut st = slot.gc.state.lock().expect("group-commit state poisoned");
        loop {
            if st.synced >= seq {
                self.metrics.journal_synced_appends.fetch_add(1, Ordering::Relaxed);
                return Ok(());
            }
            if st.poisoned {
                return Err(ApiError::new(
                    ErrorCode::ReadOnly,
                    "write-ahead journal group sync failed (admission applied but not acked)"
                        .to_string(),
                ));
            }
            if !st.leader {
                st.leader = true;
                drop(st);
                let result = {
                    let mut j = slot.journal.lock().expect("journal lock poisoned");
                    j.group_sync()
                };
                st = slot.gc.state.lock().expect("group-commit state poisoned");
                st.leader = false;
                match result {
                    Ok(synced) => {
                        st.synced = st.synced.max(synced);
                        self.metrics.journal_group_commits.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        st.poisoned = true;
                        self.note_journal_failure(&e);
                        slot.gc.cv.notify_all();
                        return Err(ApiError::new(
                            ErrorCode::ReadOnly,
                            format!(
                                "write-ahead journal group sync failed \
                                 (admission applied but not acked): {e}"
                            ),
                        ));
                    }
                }
                slot.gc.cv.notify_all();
                // Loop: the sync we just led covers our own seq.
            } else {
                let (guard, _) = slot
                    .gc
                    .cv
                    .wait_timeout(st, GROUP_COMMIT_PARK)
                    .expect("group-commit state poisoned");
                st = guard;
                // Timeout path re-checks and may self-promote (the loop).
            }
        }
    }

    /// Checkpoint-truncate shard `idx`'s journal when due. Called with
    /// that shard's scheduler mutex held, after a successful admission.
    /// Checkpoint failure poisons the journal (subsequent admissions fail
    /// typed) but the admission that triggered it was already durable in
    /// the old segment, so nothing acked is lost.
    fn maybe_checkpoint_locked(&self, idx: usize, sched: &Scheduler) {
        let (Some(store), Some(dcfg)) = (&self.journal, &self.cfg.durability) else {
            return;
        };
        let slot = &store.slots[idx];
        let mut j = slot.journal.lock().expect("journal lock poisoned");
        if j.is_poisoned() || !j.checkpoint_due(dcfg) {
            return;
        }
        if store.group_commit {
            // Make the deferred tail durable *before* history rotates: a
            // torn rotation must never take unsynced acks down with it —
            // with the tail synced first, a checkpoint failure still lets
            // every parked writer (including the admission that tripped the
            // stride) ack off the old segment.
            match j.group_sync() {
                Ok(synced) => {
                    let mut st = slot.gc.state.lock().expect("group-commit state poisoned");
                    st.synced = st.synced.max(synced);
                    self.metrics.journal_group_commits.fetch_add(1, Ordering::Relaxed);
                    drop(st);
                    slot.gc.cv.notify_all();
                }
                Err(e) => {
                    self.note_journal_failure(&e);
                    eprintln!("spotcloud: journal sync before checkpoint failed: {e}");
                    return;
                }
            }
        }
        let state = self.capture_checkpoint_locked(idx, sched);
        if let Err(e) = j.checkpoint(&state) {
            self.note_journal_failure(&e);
            eprintln!("spotcloud: journal checkpoint failed (journal now read-only): {e}");
        }
    }

    /// Capture shard `idx`'s full durable state under its scheduler
    /// mutex. Live terminal jobs (ended but not yet retired) are captured
    /// as history views, not as live jobs — recovery re-queues every live
    /// job, and re-running a completed job would violate exactly-once.
    ///
    /// Sharded captures carry the *global* manifest registry and history
    /// (stamped with `global_seq` so recovery keeps the newest registry
    /// authoritative), this shard's live jobs, the global id-allocator
    /// value as `next_id`, and the shard's applied-lease watermark. The
    /// watermark is read under the same mutex that orders lease
    /// applications, so `applied_lease >= L` certifies this checkpoint's
    /// registry and job table absorbed lease `L`'s local part.
    fn capture_checkpoint_locked(&self, idx: usize, sched: &Scheduler) -> CheckpointState {
        let registry = self.manifests.read().expect("manifests poisoned");
        let history = self.history.read().expect("history poisoned");
        let (global_seq, applied_lease) = match &self.journal {
            // Sequenced under the registry read lock: a checkpoint with a
            // higher global_seq always carries a superset registry.
            Some(store) => (
                store.global_seq.fetch_add(1, Ordering::SeqCst) + 1,
                store.slots[idx].applied_lease.load(Ordering::SeqCst),
            ),
            None => (0, 0),
        };
        let next_id = if self.shards.is_sharded() {
            self.shards.next_id()
        } else {
            sched.jobs_signature().1
        };
        let mut jobs = Vec::new();
        let mut views = history.ordered_views();
        for job in sched.jobs() {
            if job.state.is_terminal() {
                views.push(JobView::of(job, sched.log()));
            } else {
                jobs.push(CheckpointJob {
                    id: job.id.0,
                    state: job.state,
                    submit_time: job.submit_time,
                    requeue_count: job.requeue_count,
                    spec: job.spec.clone(),
                    log: sched
                        .log()
                        .for_job(job.id)
                        .map(|e| (e.time, e.kind))
                        .collect(),
                });
            }
        }
        CheckpointState {
            vtime: sched.now(),
            next_id,
            next_manifest_id: registry.next_id(),
            jobs,
            history: views,
            manifests: registry.iter().cloned().collect(),
            global_seq,
            applied_lease,
        }
    }

    /// Advance every scheduler shard to the current wall-paced virtual
    /// time, harvest newly dispatched tracked jobs into the metrics, retire
    /// old terminal jobs into the history side-table, and publish.
    pub fn pace(&self) {
        // The health probe rides the pacer tick so the state machine
        // advances (and recovers) even on an idle daemon with no request
        // traffic to piggyback on.
        self.maybe_probe_health();
        for idx in 0..self.shards.count() {
            self.pace_shard(idx);
        }
    }

    /// Pace one shard. The tracked-job harvest is shard-agnostic: ids that
    /// live on another shard simply have no `DispatchDone` record in this
    /// shard's log and stay tracked until their own shard's sweep.
    fn pace_shard(&self, idx: usize) {
        self.with_shard_mut(idx, |sched| {
            let target = self.target_now();
            if target > sched.now() {
                sched.run_until(target);
            }
            let mut tracked = self.tracked.lock().expect("tracked poisoned");
            let done: Vec<JobId> = tracked
                .iter()
                .copied()
                .filter(|&j| sched.log().last(j, LogKind::DispatchDone).is_some())
                .collect();
            for j in done {
                tracked.remove(&j);
                let rec = sched.log().first(j, LogKind::Recognized).expect("recognized");
                let dis = sched.log().last(j, LogKind::DispatchDone).expect("dispatched");
                self.metrics.record_sched_latency(dis.saturating_sub(rec).as_nanos());
            }
            drop(tracked);
            if let Some(grace) = self.cfg.retire_grace_secs {
                let retired = sched.retire_terminal(SimTime::from_secs_f64(grace));
                if !retired.is_empty() {
                    {
                        // Freeze the views *before* pruning the log — the
                        // view construction reads the retired jobs' last
                        // event-log records.
                        let mut history = self.history.write().expect("history poisoned");
                        for j in &retired {
                            history.insert_capped(
                                j.id.0,
                                Arc::new(JobView::of(j, sched.log())),
                                self.cfg.history_cap,
                            );
                        }
                    }
                    // Retired jobs' event-log entries are dead weight from
                    // here on (everything queryable lives in the frozen
                    // views): drop their indexes and let the log compact.
                    sched.prune_retired_log(retired.iter().map(|j| j.id));
                }
            }
        });
    }

    /// Spawn the pacer thread. Returns its join handle; the thread exits on
    /// shutdown.
    pub fn spawn_pacer(self: &Arc<Self>) -> std::thread::JoinHandle<()> {
        let daemon = Arc::clone(self);
        std::thread::Builder::new()
            .name("spotcloud-pacer".into())
            .spawn(move || {
                while daemon.is_running() {
                    daemon.pace();
                    std::thread::sleep(std::time::Duration::from_millis(daemon.cfg.pacer_tick_ms));
                }
            })
            .expect("spawning pacer")
    }

    // ---- read path ---------------------------------------------------------

    /// The published read view (lock-free with respect to the scheduler:
    /// only the snapshot `RwLock` is touched, and only to clone an `Arc`).
    /// Counts toward the read-path metric — client-request use only.
    pub fn read_snapshot(&self) -> Arc<SchedSnapshot> {
        self.metrics.record_read_path();
        self.snapshot()
    }

    /// Unmetered snapshot access for internal machinery (WAIT admission and
    /// polling), so waiter polling doesn't pollute the read-path counter.
    fn snapshot(&self) -> Arc<SchedSnapshot> {
        Arc::clone(&self.snapshot.read().expect("snapshot poisoned"))
    }

    // ---- overload control plane --------------------------------------------

    /// The overload-control configuration (the transports read the
    /// per-connection limits from here).
    pub fn overload_config(&self) -> &OverloadConfig {
        &self.cfg.overload
    }

    /// Current health state (decoded from the atomic).
    pub fn health_state(&self) -> HealthState {
        match self.health.load(Ordering::Relaxed) {
            2 => HealthState::ReadOnly,
            1 => HealthState::Shedding,
            _ => HealthState::Healthy,
        }
    }

    /// Transition the health state, stamping `since` on change.
    /// `ReadOnly` is sticky: once the journal poisoned, no probe outcome
    /// downgrades the state (the journal never un-poisons).
    fn set_health(&self, next: HealthState) {
        let code = match next {
            HealthState::Healthy => 0,
            HealthState::Shedding => 1,
            HealthState::ReadOnly => 2,
        };
        let now_ms = self.start.elapsed().as_millis() as u64;
        let prev = self
            .health
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                if cur == 2 || cur == code {
                    None
                } else {
                    Some(code)
                }
            });
        if prev.is_ok() {
            self.health_since_ms.store(now_ms, Ordering::Relaxed);
        }
    }

    /// Probe the health state if a probe interval elapsed since the last
    /// one (rides the pacer tick and the request path; a CAS keeps
    /// concurrent callers from double-probing).
    fn maybe_probe_health(&self) {
        let interval = self.cfg.overload.probe_interval_ms;
        let now_ms = self.start.elapsed().as_millis() as u64;
        let last = self.last_probe_ms.load(Ordering::Relaxed);
        if now_ms.saturating_sub(last) < interval.max(1) {
            return;
        }
        if self
            .last_probe_ms
            .compare_exchange(last, now_ms, Ordering::Relaxed, Ordering::Relaxed)
            .is_err()
        {
            return;
        }
        self.probe_health();
    }

    /// One health probe: measured signals only. `ReadOnly` when the
    /// journal poisoned (sticky); `Shedding` while admission pressure is
    /// observable — sheds since the last probe, the inflight gauge at the
    /// budget, or the write-lock hold p99 over its threshold — `Healthy`
    /// otherwise. Recovery is therefore bounded by one probe interval
    /// after the pressure stops.
    pub fn probe_health(&self) {
        if self.metrics.journal_poisoned.load(Ordering::Relaxed) > 0 {
            self.set_health(HealthState::ReadOnly);
            return;
        }
        let ov = &self.cfg.overload;
        let sheds = self.sheds_since_probe.swap(0, Ordering::Relaxed);
        let at_budget =
            ov.inflight_budget > 0 && self.inflight.load(Ordering::Relaxed) >= ov.inflight_budget;
        let slow_locks =
            ov.lock_p99_shed_ns > 0 && self.metrics.lock_hold().p99() > ov.lock_p99_shed_ns;
        if sheds > 0 || at_budget || slow_locks {
            self.set_health(HealthState::Shedding);
        } else {
            self.set_health(HealthState::Healthy);
        }
    }

    /// Count one shed event toward the next probe's `Shedding` decision.
    fn note_shed(&self) {
        self.sheds_since_probe.fetch_add(1, Ordering::Relaxed);
    }

    /// The `HEALTH` response (also embedded in v2 `STATS`).
    pub fn health_report(&self) -> HealthReport {
        let now_ms = self.start.elapsed().as_millis() as u64;
        let since_ms = self.health_since_ms.load(Ordering::Relaxed);
        HealthReport {
            state: self.health_state(),
            since_secs: now_ms.saturating_sub(since_ms) as f64 / 1000.0,
            inflight: self.inflight.load(Ordering::Relaxed),
            inflight_budget: self.cfg.overload.inflight_budget,
            shed_submits: self.metrics.shed_submits.load(Ordering::Relaxed),
            shed_msubmits: self.metrics.shed_msubmits.load(Ordering::Relaxed),
            rate_limited: self.metrics.shed_rate_limited.load(Ordering::Relaxed),
            deadline_expired: self.metrics.deadline_expired.load(Ordering::Relaxed),
            conns_evicted: self.metrics.conns_evicted.load(Ordering::Relaxed),
            journal_poisoned: self.metrics.journal_poisoned.load(Ordering::Relaxed),
        }
    }

    /// Admission gate, run before any scheduler lock. Total over every
    /// request: a deadline budget already spent drops the request typed;
    /// sheddable verbs (`SUBMIT`/`MSUBMIT`) then pass the per-user rate
    /// limit and the global inflight budget. Everything else — reads,
    /// `WAIT`, control verbs — is never shed (`Ok` with no gauge hold).
    fn gate(&self, req: &Request, expires: Option<Instant>) -> Result<InflightGuard<'_>, ApiError> {
        if let Some(at) = expires {
            if Instant::now() >= at {
                self.metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
                return Err(ApiError::overloaded(
                    "deadline budget exhausted before execution (request dropped unexecuted)",
                    0,
                ));
            }
        }
        match req {
            Request::Submit(spec) => {
                self.admit_sheddable(Some(spec.user), &self.metrics.shed_submits)
            }
            Request::MSubmit(m) => self.admit_sheddable(
                m.entries.first().map(|e| e.user),
                &self.metrics.shed_msubmits,
            ),
            // A chunk reaching the typed path is a complete single-part
            // stream (see [`Daemon::handle`]); transports with an
            // assembler gate the *assembled* admission instead.
            Request::MSubmitChunk(_) => self.admit_sheddable(None, &self.metrics.shed_msubmits),
            _ => Ok(InflightGuard(None)),
        }
    }

    /// Admit one sheddable request or refuse it cheaply: read-only
    /// refusal first (no lock, no journal attempt), then the user's token
    /// bucket, then the global inflight budget. Refusals carry
    /// `retry_after_ms` so a well-behaved client backs off exactly as
    /// long as needed.
    fn admit_sheddable<'a>(
        &'a self,
        user: Option<u32>,
        shed_counter: &AtomicU64,
    ) -> Result<InflightGuard<'a>, ApiError> {
        if self.health_state() == HealthState::ReadOnly {
            shed_counter.fetch_add(1, Ordering::Relaxed);
            return Err(ApiError::read_only(
                "write-ahead journal poisoned: daemon is read-only \
                 (submissions refused; reads and WAIT still serve)",
            ));
        }
        let ov = &self.cfg.overload;
        if ov.user_rate > 0.0 {
            if let Some(u) = user {
                let now = Instant::now();
                let mut buckets = self.user_buckets.lock().expect("user buckets poisoned");
                let bucket = buckets
                    .entry(u)
                    .or_insert_with(|| TokenBucket::new(ov.user_rate, ov.user_burst, now));
                if let Err(retry_ms) = bucket.try_take(now) {
                    drop(buckets);
                    self.metrics.shed_rate_limited.fetch_add(1, Ordering::Relaxed);
                    self.note_shed();
                    return Err(ApiError::overloaded(
                        format!("user {u} submission rate limit exceeded"),
                        retry_ms,
                    ));
                }
                if buckets.len() as u64 >= self.user_bucket_sweep_at.load(Ordering::Relaxed) {
                    Self::retire_idle_buckets(&mut buckets, now);
                    let next = (buckets.len().max(USER_BUCKET_SWEEP_MIN) as u64)
                        .saturating_mul(2)
                        .min(USER_BUCKET_HARD_CAP as u64);
                    self.user_bucket_sweep_at.store(next, Ordering::Relaxed);
                }
            }
        }
        if ov.inflight_budget > 0 {
            let prev = self.inflight.fetch_add(1, Ordering::Relaxed);
            if prev >= ov.inflight_budget {
                self.inflight.fetch_sub(1, Ordering::Relaxed);
                shed_counter.fetch_add(1, Ordering::Relaxed);
                self.note_shed();
                return Err(ApiError::overloaded(
                    format!(
                        "admission budget exhausted ({prev} requests inflight, budget {})",
                        ov.inflight_budget
                    ),
                    SHED_RETRY_MS,
                ));
            }
            return Ok(InflightGuard(Some(&self.inflight)));
        }
        Ok(InflightGuard(None))
    }

    /// Bound the per-user admission-bucket map. Retiring a refill-saturated
    /// bucket is lossless — the user's next submission re-creates an
    /// identical fresh bucket — so the sweep changes no admission decision
    /// unless the *hard* cap forces out mid-refill buckets (and that only
    /// ever errs toward admitting).
    fn retire_idle_buckets(buckets: &mut FxHashMap<u32, TokenBucket>, now: Instant) {
        buckets.retain(|_, b| !b.is_saturated(now));
        if buckets.len() <= USER_BUCKET_HARD_CAP {
            return;
        }
        // Rare: more distinct mid-refill users than the hard cap inside one
        // refill window. Evict the least-recently-touched down to half the
        // cap (O(n log n), amortized away by the sweep watermark).
        let mut by_age: Vec<(Instant, u32)> = buckets.iter().map(|(&u, b)| (b.last, u)).collect();
        by_age.sort_unstable();
        let excess = buckets.len() - USER_BUCKET_HARD_CAP / 2;
        for &(_, u) in by_age.iter().take(excess) {
            buckets.remove(&u);
        }
    }

    /// Live per-user admission token buckets (the `STATS` `buckets_live`
    /// gauge; also pinned by the eviction regression tests).
    pub fn user_bucket_count(&self) -> usize {
        self.user_buckets.lock().expect("user buckets poisoned").len()
    }

    // ---- wire front door ---------------------------------------------------

    /// Handle one v1 request line; returns the rendered response body.
    /// (Compatibility surface — the transport uses
    /// [`Daemon::handle_line_versioned`].)
    pub fn handle_line(&self, line: &str) -> String {
        self.handle_line_versioned(line, ProtocolVersion::V1).0
    }

    /// Handle one request line under `version`, blocking for `WAIT`.
    /// Returns the rendered response and, for a successful `HELLO`, the
    /// version the connection speaks from the next request on (the `HELLO`
    /// response itself is already rendered in the negotiated version).
    pub fn handle_line_versioned(
        &self,
        line: &str,
        version: ProtocolVersion,
    ) -> (String, Option<ProtocolVersion>) {
        match self.handle_line_nonblocking(line, version) {
            LineOutcome::Done(resp, negotiated) => (resp, negotiated),
            LineOutcome::Parked(parked) => {
                let resp = self.block_on_wait(&parked.ticket);
                (self.finish_wait(&parked, resp), None)
            }
        }
    }

    /// Handle one request line without ever blocking the caller: a `WAIT`
    /// that cannot complete immediately comes back as
    /// [`LineOutcome::Parked`] for the transport to resume later.
    pub fn handle_line_nonblocking(&self, line: &str, version: ProtocolVersion) -> LineOutcome {
        self.handle_line_stateful(line, version, None)
    }

    /// [`Daemon::handle_line_nonblocking`] with connection-level chunked
    /// `MSUBMIT` state. The transport owns one [`ChunkAssembler`] per
    /// connection: v2.1 chunk records accumulate in it (intermediate parts
    /// answer `chunk_ack`, the final part admits the assembled manifest
    /// atomically), and while a stream is open *any* other line — a
    /// different verb or even an unparseable one — discards the partial
    /// manifest with a typed error. A chunked stream is never resumable:
    /// after any error the client re-sends from part 1.
    pub fn handle_line_stateful(
        &self,
        line: &str,
        version: ProtocolVersion,
        assembler: Option<&mut ChunkAssembler>,
    ) -> LineOutcome {
        self.handle_line_at(line, version, assembler, Instant::now())
    }

    /// [`Daemon::handle_line_stateful`] with an explicit arrival instant:
    /// the transports stamp `arrived` when the line is read off the
    /// socket, so a v2 `deadline_ms=` budget covers worker-pool queueing —
    /// a request that expired while queued is dropped here, before any
    /// scheduler lock, instead of wasting the worker turn executing it.
    pub fn handle_line_at(
        &self,
        line: &str,
        version: ProtocolVersion,
        assembler: Option<&mut ChunkAssembler>,
        arrived: Instant,
    ) -> LineOutcome {
        let t0 = Instant::now();
        self.maybe_probe_health();
        let (deadline_ms, line) = match codec::split_deadline(line, version) {
            Ok(split) => split,
            Err(e) => {
                self.metrics.record_request(false, t0.elapsed().as_nanos() as u64);
                let resp = Response::Error(e);
                return LineOutcome::Done(codec::render_response(&resp, version), None);
            }
        };
        let expires = deadline_ms.map(|ms| arrived + Duration::from_millis(ms));
        let parsed = codec::parse_request(line, version);
        let parsed = match (parsed, assembler) {
            (Ok(Request::MSubmitChunk(chunk)), Some(asm)) => {
                self.metrics.record_command("MSUBMIT");
                // The budget spans the whole part sequence: the earliest
                // deadline any part carried binds the assembled admission.
                if let Some(at) = expires {
                    asm.note_deadline(at);
                }
                let resp = if asm.deadline().map_or(false, |d| Instant::now() >= d) {
                    asm.abort();
                    self.metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
                    Response::Error(ApiError::overloaded(
                        "deadline budget exhausted mid-stream \
                         (partial manifest discarded, re-send from part 1)",
                        0,
                    ))
                } else {
                    match asm.push(chunk) {
                        Ok(ChunkOutcome::Partial {
                            part,
                            parts,
                            received,
                        }) => Response::ChunkAck {
                            part,
                            parts,
                            received,
                        },
                        Ok(ChunkOutcome::Complete(manifest)) => {
                            asm.clear_deadline();
                            let user = manifest.entries.first().map(|e| e.user);
                            match self.admit_sheddable(user, &self.metrics.shed_msubmits) {
                                Ok(_inflight) => self.msubmit_assembled(&manifest),
                                Err(e) => Response::Error(e),
                            }
                        }
                        Err(e) => {
                            asm.clear_deadline();
                            Response::Error(e)
                        }
                    }
                };
                let ok = !matches!(resp, Response::Error(_));
                self.metrics.record_request(ok, t0.elapsed().as_nanos() as u64);
                return LineOutcome::Done(codec::render_response(&resp, version), None);
            }
            (parsed, Some(asm)) if asm.in_progress() => {
                asm.abort();
                if let Ok(req) = &parsed {
                    self.metrics.record_command(req.command_name());
                }
                let resp = Response::Error(ApiError::unsupported(
                    "a chunked MSUBMIT stream was open: partial manifest discarded \
                     (re-send from part 1)",
                ));
                self.metrics.record_request(false, t0.elapsed().as_nanos() as u64);
                return LineOutcome::Done(codec::render_response(&resp, version), None);
            }
            (parsed, _) => parsed,
        };
        self.handle_parsed(parsed, version, t0, expires)
    }

    fn handle_parsed(
        &self,
        parsed: Result<Request, ApiError>,
        version: ProtocolVersion,
        t0: Instant,
        expires: Option<Instant>,
    ) -> LineOutcome {
        let (resp, render_version, negotiated) = match parsed {
            // A binary-framed connection negotiated once, at text HELLO
            // time; renegotiating mid-stream would have to re-frame the
            // transport under the client's feet, so it is a typed refusal.
            Ok(Request::Hello(_)) if version.binary_frames() => {
                self.metrics.record_command("HELLO");
                (
                    Response::Error(ApiError::unsupported(
                        "connection already speaks v3 binary framing \
                         (HELLO renegotiation inside a frame is not allowed)",
                    )),
                    version,
                    None,
                )
            }
            Ok(req) => {
                self.metrics.record_command(req.command_name());
                match self.gate(&req, expires) {
                    Err(e) => (Response::Error(e), version, None),
                    // The guard spans the `handle` call below: the gauge
                    // counts requests while they *execute*.
                    Ok(_inflight) => {
                        if let Request::Wait { jobs, timeout_secs } = &req {
                            match self.begin_wait(jobs, *timeout_secs) {
                                WaitStart::Done(resp) => (resp, version, None),
                                WaitStart::Parked(ticket) => {
                                    return LineOutcome::Parked(ParkedWait { ticket, version });
                                }
                            }
                        } else if let Request::WaitEntry {
                            manifest,
                            entry,
                            timeout_secs,
                        } = &req
                        {
                            // Per-entry WAIT parks exactly like a job-list WAIT —
                            // the manifest/entry pair resolves to its id span
                            // first, so resolution errors come back immediately.
                            match self.resolve_entry_jobs(*manifest, *entry) {
                                Ok(jobs) => match self.begin_wait(&jobs, *timeout_secs) {
                                    WaitStart::Done(resp) => (resp, version, None),
                                    WaitStart::Parked(ticket) => {
                                        return LineOutcome::Parked(ParkedWait { ticket, version });
                                    }
                                },
                                Err(e) => (Response::Error(e), version, None),
                            }
                        } else {
                            let negotiated = match &req {
                                Request::Hello(v) => Some(*v),
                                _ => None,
                            };
                            let resp = self.handle(req);
                            (resp, negotiated.unwrap_or(version), negotiated)
                        }
                    }
                }
            }
            Err(e) => (Response::Error(e), version, None),
        };
        let ok = !matches!(resp, Response::Error(_));
        self.metrics.record_request(ok, t0.elapsed().as_nanos() as u64);
        LineOutcome::Done(codec::render_response(&resp, render_version), negotiated)
    }

    /// Render a parked `WAIT`'s final response and account the request
    /// (wall latency measured from arrival, not resume).
    pub fn finish_wait(&self, parked: &ParkedWait, resp: Response) -> String {
        let ok = !matches!(resp, Response::Error(_));
        self.metrics
            .record_request(ok, parked.ticket.started.elapsed().as_nanos() as u64);
        codec::render_response(&resp, parked.version)
    }

    /// Execute one v3 binary `MSUBMIT` frame and render the complete
    /// response frame bytes. The transport parses the payload zero-copy on
    /// its reader thread ([`codec::parse_msubmit_v3`] straight off the
    /// connection buffer — no per-entry `String` ever exists) and ships the
    /// typed result here on a worker; admission gating, metrics, and the
    /// open-chunk-stream interlock match the text `MSUBMIT` path exactly.
    /// Success frames a binary `OP_MANIFEST_ACK`; every error frames an
    /// `OP_TEXT_RESP` carrying the v2 `ERR` body.
    pub fn handle_msubmit_frame(
        &self,
        parsed: Result<Manifest, ApiError>,
        assembler: Option<&mut ChunkAssembler>,
    ) -> Vec<u8> {
        let t0 = Instant::now();
        self.maybe_probe_health();
        self.metrics.record_command("MSUBMIT");
        let aborted_stream = assembler.map_or(false, |asm| asm.abort());
        let resp = if aborted_stream {
            Response::Error(ApiError::unsupported(
                "a chunked MSUBMIT stream was open: partial manifest discarded \
                 (re-send from part 1)",
            ))
        } else {
            match parsed {
                Ok(m) => {
                    let user = m.entries.first().map(|e| e.user);
                    match self.admit_sheddable(user, &self.metrics.shed_msubmits) {
                        Ok(_inflight) => self.msubmit_assembled(&m),
                        Err(e) => Response::Error(e),
                    }
                }
                Err(e) => Response::Error(e),
            }
        };
        let ok = !matches!(resp, Response::Error(_));
        self.metrics.record_request(ok, t0.elapsed().as_nanos() as u64);
        match resp {
            Response::ManifestAck(ack) => {
                codec::v3_frame(codec::OP_MANIFEST_ACK, &codec::render_manifest_ack_v3(&ack))
            }
            other => {
                let body = codec::render_response(&other, ProtocolVersion::V3);
                codec::v3_frame(codec::OP_TEXT_RESP, body.as_bytes())
            }
        }
    }

    /// Handle one typed request. Total: failures come back as
    /// [`Response::Error`]. `WAIT` blocks (the transport-level
    /// [`Daemon::handle_line_nonblocking`] parks instead).
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Hello(v) => Response::Hello(v),
            Request::Shutdown => {
                self.shutdown();
                Response::ShuttingDown
            }
            Request::Submit(spec) => self.handle_submit(&spec),
            Request::MSubmit(manifest) => self.handle_msubmit(&manifest),
            Request::MSubmitChunk(chunk) => {
                // The transport owns the per-connection stream (see
                // [`super::server`]); a chunk reaching the typed path
                // directly can only be a complete single-part stream.
                match ChunkAssembler::new().push(chunk) {
                    Ok(ChunkOutcome::Complete(m)) => self.msubmit_assembled(&m),
                    Ok(ChunkOutcome::Partial { .. }) => Response::Error(ApiError::unsupported(
                        "multi-part MSUBMIT needs a connection-level stream",
                    )),
                    Err(e) => Response::Error(e),
                }
            }
            Request::Scancel(id) => {
                // Sharded mode cannot route a bare job id (ids are global,
                // shard-blind), so probe each shard in turn; `cancel` on a
                // shard that does not own the id is a read-only miss. The
                // cancel record lands in the *owning* shard's journal.
                let mut cancelled = Ok((false, None));
                for idx in 0..self.shards.count() {
                    cancelled = self.with_shard_mut(idx, |sched| {
                        if !sched.cancel(JobId(id)) {
                            return Ok((false, None));
                        }
                        // Cancel is mutate-then-append: the scheduler state is
                        // already changed, so a journal failure here leaves the
                        // cancel applied but *unacked* — the client retries and
                        // lands on the tolerant-replay path. This is the
                        // documented at-least-once edge (see PROTOCOL.md).
                        let pending = self.journal_append(
                            idx,
                            &JournalRecord::Cancel {
                                vtime: sched.now(),
                                id,
                            },
                        )?;
                        self.maybe_checkpoint_locked(idx, sched);
                        Ok::<_, ApiError>((true, pending.map(|seq| (idx, seq))))
                    });
                    if !matches!(cancelled, Ok((false, _))) {
                        break;
                    }
                }
                match cancelled {
                    Ok((true, pending)) => {
                        if let Some((idx, seq)) = pending {
                            if let Err(e) = self.group_sync_wait(idx, seq) {
                                return Response::Error(e);
                            }
                        }
                        Response::Cancelled(id)
                    }
                    Ok((false, _)) => Response::Error(ApiError::not_found(format!(
                        "unknown or finished job {id}"
                    ))),
                    Err(e) => Response::Error(e),
                }
            }
            Request::Squeue(filter) => self.handle_squeue(&filter),
            Request::Sjob(id) => self.handle_sjob(id),
            Request::Wait { jobs, timeout_secs } => match self.begin_wait(&jobs, timeout_secs) {
                WaitStart::Done(resp) => resp,
                WaitStart::Parked(ticket) => self.block_on_wait(&ticket),
            },
            Request::WaitEntry {
                manifest,
                entry,
                timeout_secs,
            } => match self.resolve_entry_jobs(manifest, entry) {
                Ok(jobs) => match self.begin_wait(&jobs, timeout_secs) {
                    WaitStart::Done(resp) => resp,
                    WaitStart::Parked(ticket) => self.block_on_wait(&ticket),
                },
                Err(e) => Response::Error(e),
            },
            Request::Resume(target) => self.handle_resume(&target),
            Request::Stats => Response::Stats(self.stats_snapshot()),
            Request::Util => Response::Util(self.util_snapshot()),
            Request::Health => Response::Health(self.health_report()),
        }
    }

    /// Materialize the specs a submission creates: `count` repetitions of
    /// the paper's per-type expansion (individual → one spec per task).
    fn materialize(spec: &SubmitSpec) -> Vec<JobSpec> {
        let mut specs = Vec::new();
        for _ in 0..spec.count {
            let batch = match spec.qos {
                QosClass::Normal => crate::workload::interactive_burst(
                    UserId(spec.user),
                    spec.job_type,
                    spec.tasks,
                ),
                QosClass::Spot => vec![JobSpec::spot(UserId(spec.user), spec.job_type, spec.tasks)],
            };
            specs.extend(
                batch
                    .into_iter()
                    .map(|s| s.with_run_time(SimTime::from_secs_f64(spec.run_secs))),
            );
        }
        specs
    }

    fn handle_submit(&self, spec: &SubmitSpec) -> Response {
        // Degenerate shapes are typed errors at admission, on the typed
        // path too — not just at the codec (a `tasks=0` array job would
        // otherwise land unschedulable, and a `count=0` burst would ack an
        // empty id range as if it had submitted something).
        if spec.tasks == 0 {
            return Response::Error(ApiError::bad_arg("tasks", "0"));
        }
        if spec.count == 0 {
            return Response::Error(ApiError::bad_arg("count", "0"));
        }
        if !(spec.run_secs.is_finite() && spec.run_secs >= 0.0) {
            return Response::Error(ApiError::bad_arg("run_secs", &spec.run_secs.to_string()));
        }
        let expansion = match spec.qos {
            // Individual submissions expand to one job per task.
            QosClass::Normal if spec.job_type == crate::job::JobType::Individual => {
                spec.tasks as u64
            }
            _ => 1,
        };
        if spec.count as u64 * expansion > MAX_BATCH_JOBS {
            return Response::Error(ApiError::bad_arg(
                "count",
                &format!("{} (batch exceeds {MAX_BATCH_JOBS} jobs)", spec.count),
            ));
        }
        let specs = Self::materialize(spec);
        let batched = spec.count > 1;
        let total_jobs = specs.len() as u64;
        // Route by QoS: in sharded mode the submission lands on its
        // partition's shard; shard 0 (the whole scheduler) otherwise.
        let shard = self.shards.shard_for(spec.qos);
        let result = self.with_shard_mut(shard, |sched| {
            // Keep the virtual clock caught up so submissions land "now"
            // (computed under the lock: a stale target would backdate the
            // submission by the lock-wait time × speedup).
            let target = self.target_now();
            if target > sched.now() {
                sched.run_until(target);
            }
            let sharded = self.shards.is_sharded();
            let mut first_id = sched.jobs_signature().1;
            if sharded {
                // Reserve a contiguous global id range while holding this
                // shard's mutex (the ordering contract that keeps shard
                // counters behind the global allocator), and fast-forward
                // the shard's own counter to it.
                first_id = self.shards.allocate_ids(total_jobs);
                sched.force_next_id(first_id);
            }
            let mut pending = None;
            let mut lease = None;
            if self.journal.is_some() {
                // Write-ahead: journal the admission (as one synthesized
                // manifest entry — replay re-materializes the identical
                // spec list) *before* the scheduler mutates, so a journal
                // failure admits and acks nothing. The scheduler's id
                // assignment is deterministic, so the first id is known
                // before submission.
                let entry = ManifestEntry::new(spec.qos, spec.job_type, spec.tasks, spec.user)
                    .with_run_secs(spec.run_secs)
                    .with_count(spec.count);
                if sharded {
                    // Lease the id range in the allocator log first, then
                    // land the (single-part) sharded admission record in
                    // this shard's journal.
                    let l = self.lease_ids(first_id, total_jobs)?;
                    lease = Some(l);
                    pending = self.journal_append(
                        shard,
                        &JournalRecord::ShardAdmit {
                            vtime: sched.now(),
                            lease: l,
                            lease_first: first_id,
                            lease_total: total_jobs,
                            shards: vec![shard as u32],
                            manifest: None,
                            runs: vec![AdmitRun {
                                first_id,
                                entries: vec![AdmitEntry { index: 0, entry }],
                            }],
                        },
                    )?;
                } else {
                    pending = self.journal_append(
                        shard,
                        &JournalRecord::Admit {
                            vtime: sched.now(),
                            first_id,
                            total_jobs,
                            manifest: None,
                            entries: vec![AdmitEntry { index: 0, entry }],
                        },
                    )?;
                }
            }
            let ids = if batched {
                // Batched: the whole burst arrives in this one RPC.
                sched.submit_batch(specs)
            } else {
                // Single spec: client-side serialization, as the paper's
                // launcher loop submits (one submit RPC apart).
                sched.submit_burst(specs)
            };
            if let Some(l) = lease {
                self.note_applied_lease(shard, l);
            }
            self.maybe_checkpoint_locked(shard, sched);
            Ok::<_, ApiError>((ids, pending))
        });
        let (ids, pending) = match result {
            Ok(v) => v,
            Err(e) => return Response::Error(e),
        };
        if let Some(seq) = pending {
            // Group commit: the ack still waits for the fsync covering its
            // record — batched with every other writer parked here.
            if let Err(e) = self.group_sync_wait(shard, seq) {
                return Response::Error(e);
            }
        }
        self.metrics
            .jobs_submitted
            .fetch_add(ids.len() as u64, Ordering::Relaxed);
        if spec.qos == QosClass::Normal {
            let mut tracked = self.tracked.lock().expect("tracked poisoned");
            tracked.extend(ids.iter().copied());
        }
        let first = ids.first().map(|j| j.0).unwrap_or(0);
        let last = ids.last().map(|j| j.0).unwrap_or(0);
        Response::SubmitAck(SubmitAck {
            first,
            last,
            count: ids.len() as u64,
        })
    }

    /// Manifest admission: validate each entry independently, then land
    /// every accepted entry's jobs **atomically** — one scheduler lock, one
    /// batched arrival instant ([`Scheduler::submit_batch`]) — and report
    /// per-entry id ranges plus typed per-entry rejects (partial accept).
    fn handle_msubmit(&self, manifest: &Manifest) -> Response {
        self.handle_msubmit_capped(manifest, MAX_MANIFEST_ENTRIES)
    }

    /// Admit a manifest assembled from a chunked (v2.1) `MSUBMIT` stream:
    /// the per-line entry cap no longer applies, only the chunked cap and
    /// the aggregate job cap. The transport calls this when its
    /// [`ChunkAssembler`] completes.
    pub fn msubmit_assembled(&self, manifest: &Manifest) -> Response {
        self.handle_msubmit_capped(manifest, MAX_CHUNKED_MANIFEST_ENTRIES)
    }

    fn handle_msubmit_capped(&self, manifest: &Manifest, cap: usize) -> Response {
        if manifest.entries.len() > cap {
            return Response::Error(ApiError::bad_arg(
                "entries",
                &format!("{} (cap {cap})", manifest.entries.len()),
            ));
        }
        let mut rejected = Vec::new();
        let mut accepted_idx = Vec::new();
        let mut total_jobs = 0u64;
        for (i, entry) in manifest.entries.iter().enumerate() {
            match entry.validate() {
                Ok(()) => {
                    total_jobs += entry.jobs();
                    accepted_idx.push(i);
                }
                Err(error) => rejected.push(EntryReject {
                    index: i as u32,
                    error,
                }),
            }
        }
        if total_jobs > MAX_BATCH_JOBS {
            // The aggregate cap is a whole-request error: silently dropping
            // the tail of a manifest would be worse than refusing it.
            return Response::Error(ApiError::bad_arg(
                "manifest",
                &format!("materializes {total_jobs} jobs (batch cap {MAX_BATCH_JOBS})"),
            ));
        }
        // Materialize outside the lock; remember each entry's span so the
        // contiguous id range submit_batch assigns can be split back out.
        let mut specs = Vec::with_capacity(total_jobs as usize);
        let mut spans = Vec::with_capacity(accepted_idx.len());
        for &i in &accepted_idx {
            let batch = manifest.entries[i].materialize();
            spans.push((i, specs.len(), batch.len()));
            specs.extend(batch);
        }
        let (ids, manifest_id) = if specs.is_empty() {
            (Vec::new(), None)
        } else if self.shards.is_sharded() {
            // Cross-partition manifests lock every touched shard and land
            // as one contiguous global id range — see
            // [`Daemon::admit_manifest_sharded`].
            match self.admit_manifest_sharded(manifest, &spans, specs, total_jobs) {
                Ok(pair) => pair,
                Err(e) => return Response::Error(e),
            }
        } else {
            // A manifest with at least one accepted entry gets a registry
            // id; the id is pre-read so the journal record carries it (the
            // registry assigns ids sequentially, and registration happens
            // under the same scheduler lock).
            let result = self.with_sched_mut(|sched| {
                // Keep the virtual clock caught up so the whole manifest
                // lands "now" (computed under the lock, same as SUBMIT).
                let target = self.target_now();
                if target > sched.now() {
                    sched.run_until(target);
                }
                let mid = self.manifests.read().expect("manifests poisoned").next_id();
                let mut pending = None;
                if self.journal.is_some() {
                    // Write-ahead, same contract as SUBMIT: the record
                    // lands durably before the scheduler or registry
                    // mutate, so a journal failure admits nothing.
                    let entries = spans
                        .iter()
                        .map(|&(i, _, _)| AdmitEntry {
                            index: i as u32,
                            entry: manifest.entries[i].clone(),
                        })
                        .collect();
                    pending = self.journal_append(
                        0,
                        &JournalRecord::Admit {
                            vtime: sched.now(),
                            first_id: sched.jobs_signature().1,
                            total_jobs,
                            manifest: Some(mid),
                            entries,
                        },
                    )?;
                }
                let ids = sched.submit_batch(specs);
                let reg_spans = spans
                    .iter()
                    .map(|&(i, start, len)| ManifestSpan {
                        index: i as u32,
                        first: ids[start].0,
                        count: len as u64,
                        tag: manifest.entries[i].tag.clone(),
                    })
                    .collect();
                let registered = self
                    .manifests
                    .write()
                    .expect("manifests poisoned")
                    .register(reg_spans);
                debug_assert_eq!(registered, Some(mid));
                self.maybe_checkpoint_locked(0, sched);
                Ok::<_, ApiError>((ids, Some(mid), pending))
            });
            match result {
                Ok((ids, mid, pending)) => {
                    if let Some(seq) = pending {
                        if let Err(e) = self.group_sync_wait(0, seq) {
                            return Response::Error(e);
                        }
                    }
                    (ids, mid)
                }
                Err(e) => return Response::Error(e),
            }
        };
        debug_assert_eq!(ids.len() as u64, total_jobs);
        self.metrics
            .jobs_submitted
            .fetch_add(ids.len() as u64, Ordering::Relaxed);
        let mut accepted = Vec::with_capacity(spans.len());
        {
            let mut tracked = self.tracked.lock().expect("tracked poisoned");
            for &(i, start, len) in &spans {
                let entry_ids = &ids[start..start + len];
                if manifest.entries[i].qos == QosClass::Normal {
                    // Interactive entries feed the daemon's Figure-2
                    // latency histogram, like the legacy SUBMIT path.
                    tracked.extend(entry_ids.iter().copied());
                }
                accepted.push(EntryAck {
                    index: i as u32,
                    first: entry_ids.first().map(|j| j.0).unwrap_or(0),
                    last: entry_ids.last().map(|j| j.0).unwrap_or(0),
                    count: len as u64,
                });
            }
        }
        Response::ManifestAck(ManifestAck {
            accepted,
            rejected,
            jobs: ids.len() as u64,
            manifest: manifest_id,
        })
    }

    /// Sharded manifest admission. Accepted entries are grouped into
    /// consecutive same-shard runs (manifest order preserved); every
    /// touched shard is locked in **ascending index order** (the global
    /// lock order that keeps cross-partition manifests deadlock-free),
    /// then ONE contiguous global id range is reserved and split across
    /// the runs with [`Scheduler::force_next_id`] — so a heterogeneous
    /// manifest's ids are contiguous and ascending in manifest order even
    /// when its entries land on different schedulers. Registration happens
    /// while all touched shards are still locked; the merged snapshot is
    /// published once, after the locks drop. (A publish racing from
    /// another writer may momentarily merge a prefix of the touched
    /// shards' slots — admission itself, the id range, and the ack are
    /// atomic regardless.)
    fn admit_manifest_sharded(
        &self,
        manifest: &Manifest,
        spans: &[(usize, usize, usize)],
        specs: Vec<JobSpec>,
        total_jobs: u64,
    ) -> Result<(Vec<JobId>, Option<u64>), ApiError> {
        // Consecutive same-shard entries collapse into one submit_batch
        // run; each run remembers its entry indices so the per-shard
        // journal parts can carry exactly their own runs.
        struct Run {
            shard: usize,
            jobs: usize,
            /// Indices into `spans` (manifest order preserved).
            entries: Vec<usize>,
        }
        let mut runs: Vec<Run> = Vec::new();
        for (k, &(i, _, len)) in spans.iter().enumerate() {
            let shard = self.shards.shard_for(manifest.entries[i].qos);
            match runs.last_mut() {
                Some(r) if r.shard == shard => {
                    r.jobs += len;
                    r.entries.push(k);
                }
                _ => runs.push(Run {
                    shard,
                    jobs: len,
                    entries: vec![k],
                }),
            }
        }
        let mut touched: Vec<usize> = runs.iter().map(|r| r.shard).collect();
        touched.sort_unstable();
        touched.dedup();
        let mut guards: Vec<(usize, std::sync::MutexGuard<'_, Scheduler>)> = touched
            .iter()
            .map(|&idx| (idx, self.shards.lock(idx)))
            .collect();
        let t0 = Instant::now();
        // Clock catch-up on every touched shard, so the whole manifest
        // lands at one virtual instant on each of them.
        let target = self.target_now();
        for (_, g) in guards.iter_mut() {
            if target > g.now() {
                g.run_until(target);
            }
        }
        let first = self.shards.allocate_ids(total_jobs);
        let mid = self.manifests.read().expect("manifests poisoned").next_id();
        // Each run's first id: one contiguous global range split in
        // manifest order.
        let mut run_first = Vec::with_capacity(runs.len());
        {
            let mut next = first;
            for r in &runs {
                run_first.push(next);
                next += r.jobs as u64;
            }
            debug_assert_eq!(next, first + total_jobs);
        }
        // Write-ahead, sharded: lease the id range in the allocator log,
        // then append one `ShardAdmit` part per touched shard (ascending
        // index order), each carrying the full lease header plus that
        // shard's runs. A failure at any point aborts before any
        // scheduler mutates — parts already appended become a torn lease
        // recovery drops whole (the client was never acked).
        let mut lease = None;
        let mut pending: Vec<(usize, u64)> = Vec::new();
        if self.journal.is_some() {
            let l = self.lease_ids(first, total_jobs)?;
            lease = Some(l);
            let declared: Vec<u32> = touched.iter().map(|&s| s as u32).collect();
            for (pos, &shard) in touched.iter().enumerate() {
                let part_runs: Vec<AdmitRun> = runs
                    .iter()
                    .zip(&run_first)
                    .filter(|(r, _)| r.shard == shard)
                    .map(|(r, &rf)| AdmitRun {
                        first_id: rf,
                        entries: r
                            .entries
                            .iter()
                            .map(|&k| {
                                let (i, _, _) = spans[k];
                                AdmitEntry {
                                    index: i as u32,
                                    entry: manifest.entries[i].clone(),
                                }
                            })
                            .collect(),
                    })
                    .collect();
                let vtime = guards[pos].1.now();
                if let Some(seq) = self.journal_append(
                    shard,
                    &JournalRecord::ShardAdmit {
                        vtime,
                        lease: l,
                        lease_first: first,
                        lease_total: total_jobs,
                        shards: declared.clone(),
                        manifest: Some(mid),
                        runs: part_runs,
                    },
                )? {
                    pending.push((shard, seq));
                }
            }
        }
        let mut ids: Vec<JobId> = Vec::with_capacity(total_jobs as usize);
        let mut spec_iter = specs.into_iter();
        for (r, &rf) in runs.iter().zip(&run_first) {
            let pos = guards
                .iter()
                .position(|&(s, _)| s == r.shard)
                .expect("run shard is locked");
            let g = &mut guards[pos].1;
            g.force_next_id(rf);
            let run_specs: Vec<JobSpec> = spec_iter.by_ref().take(r.jobs).collect();
            let run_ids = g.submit_batch(run_specs);
            debug_assert_eq!(run_ids.first().map(|j| j.0), Some(rf));
            ids.extend(run_ids);
        }
        debug_assert_eq!(ids.len() as u64, total_jobs);
        let reg_spans = spans
            .iter()
            .map(|&(i, start, len)| ManifestSpan {
                index: i as u32,
                first: ids[start].0,
                count: len as u64,
                tag: manifest.entries[i].tag.clone(),
            })
            .collect();
        let registered = self
            .manifests
            .write()
            .expect("manifests poisoned")
            .register(reg_spans);
        debug_assert_eq!(registered, Some(mid));
        // The applied-lease watermark moves only after the registry holds
        // the manifest and every run landed, so a checkpoint claiming
        // `applied_lease >= L` always carries lease L's full effects.
        if let Some(l) = lease {
            for &shard in &touched {
                self.note_applied_lease(shard, l);
            }
        }
        for (idx, g) in guards.iter() {
            self.maybe_checkpoint_locked(*idx, g);
            self.shards.store_snapshot(*idx, g);
        }
        let hold_ns = t0.elapsed().as_nanos() as u64;
        drop(guards);
        for &idx in &touched {
            self.shards.record_hold(idx, hold_ns);
        }
        self.metrics.record_write_lock(hold_ns);
        self.publish_merged();
        // Group commit: the ack waits for every touched shard's covering
        // sync, after the scheduler locks drop.
        for (shard, seq) in pending {
            self.group_sync_wait(shard, seq)?;
        }
        Ok((ids, Some(mid)))
    }

    fn handle_squeue(&self, filter: &SqueueFilter) -> Response {
        let snap = self.read_snapshot();
        let states: Vec<JobState> = match filter.state {
            Some(s) => vec![s],
            None => vec![JobState::Pending, JobState::Running, JobState::Requeued],
        };
        let limit = filter.limit.unwrap_or(usize::MAX);
        let mut rows = Vec::new();
        'outer: for st in states {
            for v in snap.jobs_in_state(st) {
                if filter.user.is_some_and(|u| v.user != u) {
                    continue;
                }
                if filter.qos.is_some_and(|q| v.qos != q) {
                    continue;
                }
                rows.push(JobSummary {
                    id: v.id,
                    job_type: v.job_type,
                    tasks: v.tasks,
                    user: v.user,
                    qos: v.qos,
                    state: v.state,
                    tag: Some(Arc::clone(&v.tag)),
                });
                if rows.len() >= limit {
                    break 'outer;
                }
            }
        }
        Response::Jobs(rows)
    }

    fn handle_sjob(&self, id: u64) -> Response {
        let snap = self.read_snapshot();
        if let Some(v) = snap.job(id) {
            return Response::Job(Self::detail_of(v));
        }
        // Retired terminal jobs answer from the history side-table, so a
        // bounded published table does not break `SJOB` for old ids.
        if let Some(v) = self.history.read().expect("history poisoned").get(&id) {
            return Response::Job(Self::detail_of(v));
        }
        Response::Error(ApiError::not_found(format!("unknown job {id}")))
    }

    fn detail_of(v: &JobView) -> JobDetail {
        JobDetail {
            id: v.id,
            job_type: v.job_type,
            tasks: v.tasks,
            user: v.user,
            qos: v.qos,
            state: v.state,
            submit_secs: v.submit_secs,
            queue_secs: v.queue_secs,
            start_secs: v.start_secs,
            end_secs: v.end_secs,
            requeues: v.requeues,
            recognized_secs: v.recognized.map(SimTime::as_secs_f64),
            dispatched_secs: v.dispatched.map(SimTime::as_secs_f64),
            latency_ns: v.latency_ns(),
            tag: Some(Arc::clone(&v.tag)),
        }
    }

    // ---- RESUME: manifest re-attach ---------------------------------------

    /// `RESUME`: resolve a manifest (by id, or the latest under a tag) and
    /// report each accepted entry's settlement, so a reconnecting client
    /// collects exactly the not-yet-settled entries. An id missing from
    /// both the snapshot and the history table counts as settled — the
    /// history cap only ever evicts *retired* (terminal) jobs, which can
    /// never dispatch again.
    fn handle_resume(&self, target: &ResumeTarget) -> Response {
        let registry = self.manifests.read().expect("manifests poisoned");
        let found = match target {
            ResumeTarget::Manifest(id) => registry.get(*id),
            ResumeTarget::Tag(tag) => registry.by_tag(tag),
        };
        let Some(m) = found else {
            return Response::Error(ApiError::not_found(match target {
                ResumeTarget::Manifest(id) => format!("unknown manifest {id}"),
                ResumeTarget::Tag(tag) => format!("no manifest tagged {tag}"),
            }));
        };
        let snap = self.read_snapshot();
        let history = self.history.read().expect("history poisoned");
        let entries = m
            .spans
            .iter()
            .map(|span| {
                let settled = span
                    .ids()
                    .filter(|&id| {
                        snap.job(id)
                            .or_else(|| history.get(&id).map(Arc::as_ref))
                            .map_or(true, JobView::settled)
                    })
                    .count() as u64;
                ResumeEntry {
                    index: span.index,
                    first: span.first,
                    count: span.count,
                    settled,
                    tag: span.tag.clone(),
                }
            })
            .collect();
        Response::Resume(ResumeInfo {
            manifest: m.id,
            entries,
        })
    }

    /// Resolve a `WAIT manifest=<id> entry=<k>` pair to its job-id span.
    fn resolve_entry_jobs(&self, manifest: u64, entry: u32) -> Result<Vec<u64>, ApiError> {
        let registry = self.manifests.read().expect("manifests poisoned");
        match registry.span(manifest, entry) {
            Some(span) => Ok(span.ids().collect()),
            None => Err(ApiError::not_found(format!(
                "unknown manifest {manifest} entry {entry}"
            ))),
        }
    }

    // ---- WAIT: subscription model -----------------------------------------

    /// Admit a `WAIT`: validate, and either answer immediately (invalid
    /// timeout, unknown job, empty list, already settled) or park a ticket
    /// on the completion hub.
    pub fn begin_wait(&self, jobs: &[u64], timeout_secs: f64) -> WaitStart {
        if !(timeout_secs.is_finite() && (0.0..=MAX_WAIT_SECS).contains(&timeout_secs)) {
            return WaitStart::Done(Response::Error(ApiError::bad_arg(
                "timeout",
                &format!("{timeout_secs}"),
            )));
        }
        // Nothing to wait for: return immediately instead of blocking until
        // the timeout (regression: empty `jobs` used to hang/err).
        if jobs.is_empty() {
            return WaitStart::Done(Response::Wait(WaitResult {
                requested: 0,
                dispatched: 0,
                timed_out: false,
                latency_ns: 0,
            }));
        }
        let snap = self.snapshot();
        {
            let history = self.history.read().expect("history poisoned");
            for &id in jobs {
                // Retired jobs are terminal (settled), answered from
                // history below — only a never-seen id is unknown.
                if snap.job(id).is_none() && !history.contains_key(&id) {
                    return WaitStart::Done(Response::Error(ApiError::not_found(format!(
                        "unknown job {id}"
                    ))));
                }
            }
        }
        let (wv, pruned) = self.wait_view(&snap, jobs);
        if let Some(id) = pruned {
            // Evicted between the existence check above and this read.
            return WaitStart::Done(Response::Error(ApiError::not_found(format!(
                "unknown job {id}"
            ))));
        }
        if wv.settled {
            return WaitStart::Done(wait_response(jobs.len(), wv, false));
        }
        self.metrics.waits_parked.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        WaitStart::Parked(WaitTicket {
            jobs: jobs.to_vec(),
            deadline: now + Duration::from_secs_f64(timeout_secs),
            started: now,
        })
    }

    /// Evaluate a `WAIT` over the published snapshot **with the history
    /// side-table folded in**, so a job retired mid-wait (or before the
    /// request) still reports its dispatch and true latency instead of
    /// silently dropping to `dispatched=0`. The second value is `Some(id)`
    /// for an id found in neither place — admission checked existence, so
    /// mid-wait that means the record was evicted by the history cap.
    fn wait_view(&self, snap: &SchedSnapshot, ids: &[u64]) -> (WaitView, Option<u64>) {
        let history = self.history.read().expect("history poisoned");
        let mut pruned = None;
        let wv = wait_view_of(ids.iter().map(|&id| {
            let view = snap.job(id).or_else(|| history.get(&id).map(Arc::as_ref));
            if view.is_none() && pruned.is_none() {
                pruned = Some(id);
            }
            view
        }));
        (wv, pruned)
    }

    /// Poll a parked `WAIT` against the current snapshot: `Some` exactly
    /// once — when it settled, timed out, or the daemon is shutting down.
    pub fn poll_wait(&self, ticket: &WaitTicket) -> Option<Response> {
        let snap = self.snapshot();
        let (wv, pruned) = self.wait_view(&snap, &ticket.jobs);
        let resp = if let Some(id) = pruned {
            // The record was evicted by `history_cap` while we waited: its
            // dispatch facts are gone, so answer the documented typed
            // not_found rather than a fabricated `dispatched=0`.
            Response::Error(ApiError::not_found(format!(
                "job {id} was pruned from history while waiting"
            )))
        } else if wv.settled {
            wait_response(ticket.jobs.len(), wv, false)
        } else if Instant::now() >= ticket.deadline {
            wait_response(ticket.jobs.len(), wv, true)
        } else if !self.is_running() {
            Response::Error(ApiError::unsupported("daemon is shutting down"))
        } else {
            return None;
        };
        self.metrics.waits_resumed.fetch_add(1, Ordering::Relaxed);
        Some(resp)
    }

    /// Block the calling thread on a parked `WAIT`. Paces the scheduler
    /// itself between hub wakes, so it works with or without the pacer
    /// thread (exactly like the old polling `WAIT`, minus the busy loop:
    /// a `DispatchDone` notify ends the sleep early).
    fn block_on_wait(&self, ticket: &WaitTicket) -> Response {
        loop {
            self.pace();
            // Read the generation *after* pacing so our own publish cannot
            // spuriously end the sleep, but any concurrent publish can.
            let gen = self.hub.generation();
            if let Some(resp) = self.poll_wait(ticket) {
                return resp;
            }
            let remaining = ticket.deadline.saturating_duration_since(Instant::now());
            self.hub.wait_change(gen, remaining.min(WAIT_POLL));
        }
    }

    /// Current completion generation (server waiter thread).
    pub fn completion_generation(&self) -> u64 {
        self.hub.generation()
    }

    /// Park until the completion generation moves past `seen` or `timeout`
    /// elapses; returns the observed generation (server waiter thread).
    pub fn wait_completion(&self, seen: u64, timeout: Duration) -> u64 {
        self.hub.wait_change(seen, timeout)
    }

    /// Wake the waiter machinery without claiming progress (the server
    /// kicks this when it parks a new connection so its waiter thread
    /// re-computes the nearest deadline).
    pub fn kick_waiters(&self) {
        self.hub.notify();
    }

    /// Register a completion waker: invoked on every completion notify
    /// (dispatch/terminal progress, shutdown, kicks). The Linux reactor
    /// subscribes an eventfd write here so parked-`WAIT` progress wakes
    /// `epoll_wait` directly — no dedicated waiter thread. The callback
    /// must be cheap and must not call back into the daemon.
    pub fn subscribe_completions(&self, f: Box<dyn Fn() + Send + Sync>) -> u64 {
        self.hub.subscribe(f)
    }

    /// Remove a waker registered with [`Daemon::subscribe_completions`].
    pub fn unsubscribe_completions(&self, id: u64) {
        self.hub.unsubscribe(id)
    }

    /// Fail a parked wait without waiting (waiter-registry overflow or a
    /// park/shutdown race). Counts as its one resolution.
    pub fn reject_wait(&self, _ticket: &WaitTicket, why: &str) -> Response {
        self.metrics.waits_resumed.fetch_add(1, Ordering::Relaxed);
        Response::Error(ApiError::unsupported(why))
    }

    fn stats_snapshot(&self) -> StatsSnapshot {
        let snap = self.read_snapshot();
        let st = &snap.stats;
        let hist = self.metrics.sched_latency();
        StatsSnapshot {
            virtual_now_secs: snap.virtual_now.as_secs_f64(),
            dispatches: st.dispatches,
            preemptions: st.preemptions,
            requeues: st.requeues,
            cron_passes: st.cron_passes,
            main_passes: st.main_passes,
            backfill_passes: st.backfill_passes,
            triggered_passes: st.triggered_passes,
            score_batches: st.score_batches,
            jobs_scored: st.jobs_scored,
            scorer: snap.scorer.to_string(),
            requests_ok: self.metrics.requests_ok.load(Ordering::Relaxed),
            requests_err: self.metrics.requests_err.load(Ordering::Relaxed),
            jobs_submitted: self.metrics.jobs_submitted.load(Ordering::Relaxed),
            sched_latency_count: hist.count(),
            sched_latency_p50_ns: hist.p50(),
            commands: self
                .metrics
                .command_counts()
                .into_iter()
                .map(|(cmd, n)| (cmd.to_ascii_lowercase(), n))
                .collect(),
            contention: Some(self.contention_stats()),
            shards: self.shard_stats(),
            journal: self.journal.as_ref().map(|_| JournalStats {
                appends: self.metrics.journal_appends.load(Ordering::Relaxed),
                synced_appends: self.metrics.journal_synced_appends.load(Ordering::Relaxed),
                group_commits: self.metrics.journal_group_commits.load(Ordering::Relaxed),
                poisoned: self.metrics.journal_poisoned.load(Ordering::Relaxed),
            }),
            health: Some(self.health_report()),
            users: Some(UserScaleStats {
                users_active: snap.users_active as u64,
                users_tracked: snap.users_tracked as u64,
                buckets_live: self.user_bucket_count() as u64,
            }),
        }
    }

    /// Per-shard stat rows: one `kind=reactor` row per registered reactor
    /// shard, plus one `kind=sched` row per scheduler shard when the back
    /// end is sharded. Empty on an unsharded daemon with no reactor (the
    /// v1-compatible shape).
    fn shard_stats(&self) -> Vec<ShardStats> {
        let mut rows = Vec::new();
        for r in self.metrics.reactor_shards() {
            rows.push(ShardStats {
                kind: ShardKind::Reactor,
                index: r.index as u32,
                label: "reactor".to_string(),
                wakeups: r.wakeups.load(Ordering::Relaxed),
                events: r.ready_events.load(Ordering::Relaxed),
                connections: r.connections.load(Ordering::Relaxed),
                parked: r.parked_waits.load(Ordering::Relaxed),
                queue_depth: 0,
                lock_hold_p99_ns: 0,
            });
        }
        if self.shards.is_sharded() {
            for s in self.shards.stats() {
                rows.push(ShardStats {
                    kind: ShardKind::Sched,
                    index: s.index as u32,
                    label: s.label,
                    wakeups: s.locks,
                    events: s.dispatches,
                    connections: 0,
                    parked: 0,
                    queue_depth: s.pending as u64,
                    lock_hold_p99_ns: s.lock_hold_p99_ns,
                });
            }
        }
        rows
    }

    /// Lock-path contention counters for the STATS v2 extension.
    fn contention_stats(&self) -> ContentionStats {
        let lock_hold = self.metrics.lock_hold();
        ContentionStats {
            read_path_ops: self.metrics.read_path_ops.load(Ordering::Relaxed),
            write_locks: self.metrics.write_locks.load(Ordering::Relaxed),
            waits_parked: self.metrics.waits_parked.load(Ordering::Relaxed),
            waits_resumed: self.metrics.waits_resumed.load(Ordering::Relaxed),
            lock_hold_count: lock_hold.count(),
            lock_hold_p50_ns: lock_hold.p50(),
            lock_hold_p99_ns: lock_hold.p99(),
            lock_hold_max_ns: lock_hold.max(),
        }
    }

    fn util_snapshot(&self) -> UtilSnapshot {
        let snap = self.read_snapshot();
        let shards = if self.shards.is_sharded() {
            let stats = self.shards.stats();
            (0..self.shards.count())
                .map(|idx| {
                    let s = self.shards.shard_snapshot(idx);
                    ShardUtil {
                        index: idx as u32,
                        label: stats[idx].label.clone(),
                        utilization: s.cluster.utilization,
                        idle_cores: s.cluster.idle_cores,
                        total_cores: s.cluster.total_cores,
                        pending: s.pending,
                        running: s.running,
                    }
                })
                .collect()
        } else {
            Vec::new()
        };
        UtilSnapshot {
            utilization: snap.cluster.utilization,
            idle_cores: snap.cluster.idle_cores,
            idle_nodes: snap.cluster.idle_nodes,
            total_cores: snap.cluster.total_cores,
            pending: snap.pending,
            running: snap.running,
            shards,
        }
    }

    /// Lock and inspect shard 0's scheduler — the whole scheduler on an
    /// unsharded daemon (tests + e2e reporting).
    pub fn with_scheduler<T>(&self, f: impl FnOnce(&Scheduler) -> T) -> T {
        let sched = self.shards.lock(0);
        f(&sched)
    }

    /// Lock and inspect one shard's scheduler (sharded tests).
    pub fn with_shard<T>(&self, idx: usize, f: impl FnOnce(&Scheduler) -> T) -> T {
        let sched = self.shards.lock(idx);
        f(&sched)
    }

    /// Scheduler shard count (1 on an unsharded daemon).
    pub fn shard_count(&self) -> usize {
        self.shards.count()
    }
}

/// Build the `WAIT` response for a settled/timed-out view.
fn wait_response(requested: usize, wv: WaitView, timed_out: bool) -> Response {
    Response::Wait(WaitResult {
        requested: requested as u32,
        dispatched: wv.dispatched,
        timed_out,
        latency_ns: wv.latency_ns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::coordinator::manifest::{ManifestBuilder, ManifestEntry};
    use crate::job::JobType;
    use crate::sim::SchedCosts;

    fn daemon() -> Arc<Daemon> {
        daemon_with(DaemonConfig {
            speedup: 10_000.0, // tests shouldn't wait on the wall clock
            pacer_tick_ms: 1,
            ..DaemonConfig::default()
        })
    }

    fn daemon_with(cfg: DaemonConfig) -> Arc<Daemon> {
        Daemon::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            cfg,
        )
    }

    #[test]
    fn ping_and_stats() {
        let d = daemon();
        assert_eq!(d.handle_line("PING"), "OK pong");
        assert!(d.handle_line("STATS").contains("virtual_now"));
        // Typed path.
        assert_eq!(d.handle(Request::Ping), Response::Pong);
        match d.handle(Request::Stats) {
            Response::Stats(s) => assert_eq!(s.scorer, "native"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn submit_runs_to_dispatch() {
        let d = daemon();
        let resp = d.handle_line("SUBMIT normal triple 608 1 60");
        assert!(resp.starts_with("OK jobs="), "{resp}");
        // Pace until dispatch shows up in metrics.
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        while d.metrics.sched_latency().count() == 0 {
            assert!(Instant::now() < deadline, "job never dispatched");
            d.pace();
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let h = d.metrics.sched_latency();
        assert_eq!(h.count(), 1);
        // Baseline triple-mode latency is sub-second of *virtual* time.
        assert!(h.max() < 2_000_000_000, "virtual latency {}ns", h.max());
    }

    #[test]
    fn squeue_lists_jobs() {
        let d = daemon();
        d.handle_line("SUBMIT spot triple 320 9 600");
        let out = d.handle_line("SQUEUE");
        assert!(out.contains("triple-mode 320 user9 spot"), "{out}");
    }

    #[test]
    fn squeue_filters_apply() {
        let d = daemon();
        d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::TripleMode,
            320,
            9,
        )));
        d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Normal,
            JobType::Array,
            16,
            1,
        )));
        let all = match d.handle(Request::Squeue(SqueueFilter::default())) {
            Response::Jobs(rows) => rows,
            other => panic!("{other:?}"),
        };
        assert_eq!(all.len(), 2);
        let spot_only = match d.handle(Request::Squeue(SqueueFilter {
            qos: Some(QosClass::Spot),
            ..Default::default()
        })) {
            Response::Jobs(rows) => rows,
            other => panic!("{other:?}"),
        };
        assert_eq!(spot_only.len(), 1);
        assert_eq!(spot_only[0].user, 9);
        let limited = match d.handle(Request::Squeue(SqueueFilter {
            limit: Some(1),
            ..Default::default()
        })) {
            Response::Jobs(rows) => rows,
            other => panic!("{other:?}"),
        };
        assert_eq!(limited.len(), 1);
    }

    #[test]
    fn batch_submit_creates_count_jobs_in_one_request() {
        let d = daemon();
        let resp = d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, 3)
                .with_run_secs(60.0)
                .with_count(10_000),
        ));
        match resp {
            Response::SubmitAck(ack) => {
                assert_eq!(ack.count, 10_000);
                assert_eq!(ack.last - ack.first + 1, 10_000);
            }
            other => panic!("{other:?}"),
        }
        // An oversized batch is rejected with a typed error.
        match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::Individual, 100, 3).with_count(100_000),
        )) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::BadArg),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn manifest_lands_heterogeneous_entries_atomically_with_per_entry_ids() {
        // The acceptance workload: a 10k-entry mixed manifest — interactive
        // AND spot, all three job types, more than three users (the shared
        // generator in workload::manifests, also what the CI bench gate
        // drives) — lands in ONE request with per-entry contiguous ranges.
        let d = daemon();
        let manifest = crate::workload::manifests::mixed(7, 10_000, 5);
        assert_eq!(manifest.entries.len(), 10_000);
        let writes_before = d.metrics.write_locks.load(Ordering::Relaxed);
        let ack = match d.handle(Request::MSubmit(manifest)) {
            Response::ManifestAck(a) => a,
            other => panic!("{other:?}"),
        };
        // One RPC, one scheduler lock for the whole heterogeneous batch.
        assert_eq!(d.metrics.write_locks.load(Ordering::Relaxed), writes_before + 1);
        assert_eq!(ack.rejected.len(), 0, "{:?}", ack.rejected.first());
        assert_eq!(ack.accepted.len(), 10_000);
        assert_eq!(ack.jobs, 10_000);
        assert_eq!(d.metrics.jobs_submitted.load(Ordering::Relaxed), 10_000);
        // Per-entry ranges are contiguous, in order, and disjoint.
        let mut next = ack.accepted[0].first;
        for (k, acc) in ack.accepted.iter().enumerate() {
            assert_eq!(acc.index as usize, k);
            assert_eq!(acc.first, next, "entry {k} range not contiguous");
            assert_eq!(acc.last - acc.first + 1, acc.count);
            next = acc.last + 1;
        }
        d.with_scheduler(|sched| sched.check_invariants().unwrap());
    }

    #[test]
    fn manifest_partial_accept_rejects_bad_entries_and_admits_the_rest() {
        let d = daemon();
        let manifest = ManifestBuilder::new()
            .interactive(1, JobType::Array, 64)
            .entry(ManifestEntry::new(QosClass::Normal, JobType::Array, 0, 1)) // tasks=0
            .spot(9, JobType::TripleMode, 320)
            .entry(ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9).with_count(0))
            .entry(
                ManifestEntry::new(QosClass::Normal, JobType::Individual, 4, 2)
                    .with_cores_per_task(0),
            )
            .entry(ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9).with_tag("bad tag"))
            .build();
        let ack = match d.handle(Request::MSubmit(manifest)) {
            Response::ManifestAck(a) => a,
            other => panic!("{other:?}"),
        };
        assert_eq!(ack.accepted.len(), 2);
        assert_eq!(ack.jobs, 2);
        assert_eq!(
            ack.rejected.iter().map(|r| r.index).collect::<Vec<_>>(),
            vec![1, 3, 4, 5]
        );
        for r in &ack.rejected {
            assert_eq!(r.error.code, super::super::api::ErrorCode::BadArg, "{r:?}");
        }
        // The accepted entries are live: both jobs are in the queue/table.
        for acc in &ack.accepted {
            assert!(matches!(d.handle(Request::Sjob(acc.first)), Response::Job(_)));
        }
    }

    #[test]
    fn empty_manifest_acks_zero_without_locking_the_scheduler() {
        let d = daemon();
        let writes_before = d.metrics.write_locks.load(Ordering::Relaxed);
        match d.handle(Request::MSubmit(Manifest::default())) {
            Response::ManifestAck(a) => {
                assert_eq!(a.accepted.len(), 0);
                assert_eq!(a.jobs, 0);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(d.metrics.write_locks.load(Ordering::Relaxed), writes_before);
    }

    #[test]
    fn manifest_aggregate_job_cap_is_a_whole_request_error() {
        let d = daemon();
        // Two entries, each under the per-entry cap, together above it.
        let big = ManifestEntry::new(QosClass::Normal, JobType::Individual, 1, 1)
            .with_count((MAX_BATCH_JOBS / 2 + 1) as u32);
        let manifest = ManifestBuilder::new()
            .entry(big.clone())
            .entry(big)
            .build();
        match d.handle(Request::MSubmit(manifest)) {
            Response::Error(e) => {
                assert_eq!(e.code, super::super::api::ErrorCode::BadArg);
                assert!(e.message.contains("batch cap"), "{e}");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(d.metrics.jobs_submitted.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn manifest_tags_flow_to_squeue_and_sjob() {
        let d = daemon();
        let manifest = ManifestBuilder::new()
            .spot(9, JobType::TripleMode, 320)
            .last(|e| e.with_tag("spot-backlog"))
            .build();
        let ack = match d.handle(Request::MSubmit(manifest)) {
            Response::ManifestAck(a) => a,
            other => panic!("{other:?}"),
        };
        let id = ack.accepted[0].first;
        match d.handle(Request::Sjob(id)) {
            Response::Job(detail) => assert_eq!(detail.tag.as_deref(), Some("spot-backlog")),
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Squeue(SqueueFilter::default())) {
            Response::Jobs(rows) => {
                assert_eq!(rows.len(), 1);
                assert_eq!(rows[0].tag.as_deref(), Some("spot-backlog"));
            }
            other => panic!("{other:?}"),
        }
        // The v2 wire carries the tag end to end.
        let (wire, _) = d.handle_line_versioned(&format!("SJOB id={id}"), ProtocolVersion::V2);
        assert!(wire.contains("tag=spot-backlog"), "{wire}");
    }

    #[test]
    fn manifest_interactive_entries_feed_the_latency_histogram() {
        let d = daemon();
        let manifest = ManifestBuilder::new()
            .interactive(1, JobType::TripleMode, 608)
            .last(|e| e.with_run_secs(60.0).with_tag("fig2-live"))
            .build();
        let ack = match d.handle(Request::MSubmit(manifest)) {
            Response::ManifestAck(a) => a,
            other => panic!("{other:?}"),
        };
        let wait = match d.handle(Request::Wait {
            jobs: ack.job_ids(),
            timeout_secs: 10.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        assert_eq!(wait.dispatched, 1);
        let h = d.metrics.sched_latency();
        assert_eq!(h.count(), 1, "manifest submissions must be tracked");
        assert_eq!(h.max(), wait.latency_ns);
    }

    #[test]
    fn degenerate_typed_submits_are_rejected_with_typed_errors() {
        // Regression: the typed path used to bypass the codec's checks —
        // tasks=0 landed no-op/unschedulable jobs, count=0 acked nothing.
        let d = daemon();
        for spec in [
            SubmitSpec {
                tasks: 0,
                ..SubmitSpec::new(QosClass::Normal, JobType::Array, 1, 1)
            },
            SubmitSpec::new(QosClass::Normal, JobType::Array, 64, 1).with_count(0),
            SubmitSpec::new(QosClass::Spot, JobType::TripleMode, 64, 9).with_run_secs(f64::NAN),
        ] {
            match d.handle(Request::Submit(spec.clone())) {
                Response::Error(e) => {
                    assert_eq!(e.code, super::super::api::ErrorCode::BadArg, "{spec:?}")
                }
                other => panic!("{spec:?} -> {other:?}"),
            }
        }
        assert_eq!(d.metrics.jobs_submitted.load(Ordering::Relaxed), 0);
        match d.handle(Request::Squeue(SqueueFilter::default())) {
            Response::Jobs(rows) => assert!(rows.is_empty(), "{rows:?}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn scancel_pending_job() {
        let d = daemon();
        let resp = d.handle_line("SUBMIT normal array 64 1 600");
        let id: u64 = resp
            .split("jobs=")
            .nth(1)
            .unwrap()
            .split('-')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        let out = d.handle_line(&format!("SCANCEL {id}"));
        assert!(out.starts_with("OK cancelled"), "{out}");
        // Cancelling again fails gracefully with a typed NotFound.
        match d.handle(Request::Scancel(id)) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
        let out2 = d.handle_line(&format!("SCANCEL {id}"));
        assert!(out2.starts_with("ERR"), "{out2}");
    }

    #[test]
    fn sjob_reports_detail_and_latency() {
        let d = daemon();
        let ack = match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1).with_run_secs(60.0),
        )) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        let wait = match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 10.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        assert_eq!(wait.dispatched, 1);
        match d.handle(Request::Sjob(ack.first)) {
            Response::Job(detail) => {
                assert_eq!(detail.id, ack.first);
                assert_eq!(detail.latency_ns, Some(wait.latency_ns));
                assert!(detail.dispatched_secs.is_some());
            }
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Sjob(999_999)) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wait_latency_matches_metrics_histogram() {
        let d = daemon();
        let ack = match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1).with_run_secs(60.0),
        )) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        let wait = match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 10.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        // WAIT paces the daemon itself, so the histogram harvest happened.
        let h = d.metrics.sched_latency();
        assert_eq!(h.count(), 1);
        assert_eq!(h.max(), wait.latency_ns, "WAIT must report the histogram's value");
    }

    #[test]
    fn wait_on_unknown_job_is_not_found() {
        let d = daemon();
        match d.handle(Request::Wait {
            jobs: vec![12345],
            timeout_secs: 1.0,
        }) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wait_on_cancelled_job_returns_without_timeout() {
        let d = daemon();
        // A job too large for the user limit would pend forever; cancel it
        // and WAIT must return promptly with dispatched=0.
        let ack = match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::Array, 64, 1).with_run_secs(600.0),
        )) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        assert!(matches!(
            d.handle(Request::Scancel(ack.first)),
            Response::Cancelled(_)
        ));
        let wait = match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 5.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        assert_eq!(wait.dispatched, 0);
    }

    #[test]
    fn wait_on_empty_job_list_returns_immediately() {
        // Regression: WAIT with an empty jobs list must not block until the
        // timeout (or error) — there is nothing to wait for.
        let d = daemon();
        let t0 = Instant::now();
        match d.handle(Request::Wait {
            jobs: vec![],
            timeout_secs: 30.0,
        }) {
            Response::Wait(w) => {
                assert_eq!(w.requested, 0);
                assert_eq!(w.dispatched, 0);
                assert!(!w.timed_out);
                assert_eq!(w.latency_ns, 0);
            }
            other => panic!("{other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "empty WAIT must not block"
        );
    }

    #[test]
    fn read_requests_never_take_the_scheduler_lock() {
        let d = daemon();
        d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::TripleMode,
            320,
            9,
        )));
        let writes_before = d.metrics.write_locks.load(Ordering::Relaxed);
        let reads_before = d.metrics.read_path_ops.load(Ordering::Relaxed);
        for _ in 0..50 {
            assert!(matches!(
                d.handle(Request::Squeue(SqueueFilter::default())),
                Response::Jobs(_)
            ));
            assert!(matches!(d.handle(Request::Stats), Response::Stats(_)));
            assert!(matches!(d.handle(Request::Util), Response::Util(_)));
            assert!(matches!(d.handle(Request::Sjob(1)), Response::Job(_)));
        }
        assert_eq!(
            d.metrics.write_locks.load(Ordering::Relaxed),
            writes_before,
            "a read-only request acquired the scheduler write mutex"
        );
        assert!(d.metrics.read_path_ops.load(Ordering::Relaxed) >= reads_before + 200);
    }

    #[test]
    fn bad_request_counts_as_error() {
        let d = daemon();
        let out = d.handle_line("SUBMIT nope nope nope nope");
        assert!(out.starts_with("ERR"));
        assert_eq!(d.metrics.requests_err.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn per_command_counters_accumulate() {
        let d = daemon();
        d.handle_line("PING");
        d.handle_line("PING");
        d.handle_line("SQUEUE");
        match d.handle(Request::Stats) {
            Response::Stats(s) => {
                assert_eq!(s.commands.get("ping").copied(), Some(2));
                assert_eq!(s.commands.get("squeue").copied(), Some(1));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hello_negotiates_v2_rendering() {
        let d = daemon();
        let (resp, negotiated) = d.handle_line_versioned("HELLO v2", ProtocolVersion::V1);
        assert_eq!(resp, "OK kind=hello proto=v2");
        assert_eq!(negotiated, Some(ProtocolVersion::V2));
        let (resp, _) = d.handle_line_versioned("PING", ProtocolVersion::V2);
        assert_eq!(resp, "OK kind=pong");
    }

    #[test]
    fn util_reports_cluster() {
        let d = daemon();
        let out = d.handle_line("UTIL");
        assert!(out.contains("total_cores=608"), "{out}");
        assert!(out.contains("utilization=0.0000"), "{out}");
    }

    #[test]
    fn shutdown_flips_flag() {
        let d = daemon();
        assert!(d.is_running());
        assert!(d.handle_line("SHUTDOWN").starts_with("OK"));
        assert!(!d.is_running());
    }

    #[test]
    fn stats_v2_exposes_contention_counters() {
        let d = daemon();
        d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::TripleMode,
            320,
            9,
        )));
        d.handle(Request::Squeue(SqueueFilter::default()));
        // Typed: the block is populated and consistent with the metrics.
        match d.handle(Request::Stats) {
            Response::Stats(s) => {
                let c = s.contention.expect("daemon always fills contention");
                assert!(c.write_locks >= 1, "{c:?}");
                assert!(c.read_path_ops >= 1, "{c:?}");
                assert_eq!(c.lock_hold_count, c.write_locks, "{c:?}");
            }
            other => panic!("{other:?}"),
        }
        // Wire: v2 carries the extension keys and round-trips; v1 stays on
        // the original key set.
        let (v2, _) = d.handle_line_versioned("STATS", super::ProtocolVersion::V2);
        assert!(v2.contains("read_path_ops="), "{v2}");
        assert!(v2.contains("lock_hold_p99_ns="), "{v2}");
        match codec::parse_response(&v2, super::ProtocolVersion::V2).unwrap() {
            Response::Stats(s) => assert!(s.contention.is_some()),
            other => panic!("{other:?}"),
        }
        let v1 = d.handle_line("STATS");
        assert!(!v1.contains("read_path_ops="), "{v1}");
    }

    #[test]
    fn retired_jobs_leave_squeue_but_sjob_answers_from_history() {
        // Aggressive retirement: 5 virtual seconds of grace at 10k×
        // speedup. The job completes after 1 virtual second and must leave
        // the published table shortly after.
        let d = daemon_with(DaemonConfig {
            speedup: 10_000.0,
            pacer_tick_ms: 1,
            retire_grace_secs: Some(5.0),
            ..DaemonConfig::default()
        });
        let ack = match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1).with_run_secs(1.0),
        )) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        let wait = match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 10.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        // Pace until the job is retired from the snapshot.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            d.pace();
            if d.read_snapshot().job(ack.first).is_none() {
                break;
            }
            assert!(Instant::now() < deadline, "job was never retired");
            std::thread::sleep(Duration::from_millis(2));
        }
        // Gone from every SQUEUE listing, including state=completed.
        match d.handle(Request::Squeue(SqueueFilter {
            state: Some(JobState::Completed),
            ..Default::default()
        })) {
            Response::Jobs(rows) => assert!(rows.is_empty(), "{rows:?}"),
            other => panic!("{other:?}"),
        }
        // SJOB still answers, from history, with terminal detail intact.
        match d.handle(Request::Sjob(ack.first)) {
            Response::Job(detail) => {
                assert_eq!(detail.id, ack.first);
                assert_eq!(detail.state, JobState::Completed);
                assert!(detail.end_secs.is_some());
                assert_eq!(detail.latency_ns, Some(wait.latency_ns));
            }
            other => panic!("{other:?}"),
        }
        // WAIT on the retired job settles from history with the real
        // dispatch count and latency (not a silent dispatched=0).
        match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 5.0,
        }) {
            Response::Wait(w) => {
                assert!(!w.timed_out);
                assert_eq!(w.dispatched, 1, "retired job lost its dispatch: {w:?}");
                assert_eq!(w.latency_ns, wait.latency_ns);
            }
            other => panic!("{other:?}"),
        }
        // A genuinely unknown id is still NotFound.
        match d.handle(Request::Sjob(999_999)) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn history_cap_prunes_oldest_retired_jobs_and_their_log() {
        // Three short jobs with staggered run times end (and so retire) in
        // submission order; a cap of 2 must evict the first-retired record.
        let d = daemon_with(DaemonConfig {
            speedup: 10_000.0,
            pacer_tick_ms: 1,
            retire_grace_secs: Some(2.0),
            history_cap: Some(2),
            ..DaemonConfig::default()
        });
        let mut ids = Vec::new();
        for run in [1.0, 2.0, 3.0] {
            let ack = match d.handle(Request::Submit(
                SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1).with_run_secs(run),
            )) {
                Response::SubmitAck(a) => a,
                other => panic!("{other:?}"),
            };
            let wait = match d.handle(Request::Wait {
                jobs: vec![ack.first],
                timeout_secs: 10.0,
            }) {
                Response::Wait(w) => w,
                other => panic!("{other:?}"),
            };
            assert!(!wait.timed_out);
            ids.push(ack.first);
        }
        // Pace until all three left the published table.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            d.pace();
            let snap = d.read_snapshot();
            if ids.iter().all(|&id| snap.job(id).is_none()) {
                break;
            }
            assert!(Instant::now() < deadline, "jobs were never retired");
            std::thread::sleep(Duration::from_millis(2));
        }
        // The cap held: at most 2 history records, the oldest pruned.
        assert!(d.history.read().expect("history").len() <= 2);
        match d.handle(Request::Sjob(ids[0])) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("pruned job must be not_found: {other:?}"),
        }
        match d.handle(Request::Sjob(ids[2])) {
            Response::Job(detail) => assert_eq!(detail.state, JobState::Completed),
            other => panic!("{other:?}"),
        }
        // WAIT on a pruned id is the same typed not_found.
        match d.handle(Request::Wait {
            jobs: vec![ids[0]],
            timeout_secs: 1.0,
        }) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
        // Retirement pruned the event log's per-job indexes too.
        d.with_scheduler(|sched| {
            for &id in &ids {
                assert!(
                    sched.log().last(JobId(id), LogKind::DispatchDone).is_none(),
                    "retired job {id} kept log entries"
                );
            }
        });
    }

    // ---- durability -------------------------------------------------------

    use crate::coordinator::journal::FsyncPolicy;
    use crate::testkit::crash::{faulty_durability, TempDir};

    /// A journaling daemon whose virtual clock never advances (speedup 0):
    /// admitted jobs stay pending, so settlement state is deterministic.
    fn frozen_daemon_with_journal(dcfg: DurabilityConfig) -> Arc<Daemon> {
        daemon_with(DaemonConfig {
            speedup: 0.0,
            pacer_tick_ms: 1,
            durability: Some(dcfg),
            ..DaemonConfig::default()
        })
    }

    #[test]
    fn msubmit_ack_carries_the_manifest_id_and_resume_reports_pending() {
        let tmp = TempDir::new("spotcloud-daemon-resume");
        let d = frozen_daemon_with_journal(
            DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Never),
        );
        let m = ManifestBuilder::new()
            .interactive(1, JobType::Array, 8)
            .last(|e| e.with_tag("nightly"))
            .spot(9, JobType::Array, 64)
            .build();
        let ack = match d.handle(Request::MSubmit(m)) {
            Response::ManifestAck(a) => a,
            other => panic!("{other:?}"),
        };
        assert_eq!(ack.manifest, Some(1), "first registered manifest id");
        // Resume by tag finds it; nothing has dispatched (frozen clock).
        let info = match d.handle(Request::Resume(ResumeTarget::Tag("nightly".into()))) {
            Response::Resume(info) => info,
            other => panic!("{other:?}"),
        };
        assert_eq!(info.manifest, 1);
        assert_eq!(info.entries.len(), 2);
        for e in &info.entries {
            assert_eq!(e.settled, 0, "frozen daemon cannot have settled jobs");
        }
        assert_eq!(info.pending_entries().count(), 2);
        // Resume by id is the same view.
        match d.handle(Request::Resume(ResumeTarget::Manifest(1))) {
            Response::Resume(by_id) => assert_eq!(by_id, info),
            other => panic!("{other:?}"),
        }
        // Unknown targets are typed not_found.
        for bad in [
            Request::Resume(ResumeTarget::Tag("other".into())),
            Request::Resume(ResumeTarget::Manifest(99)),
        ] {
            match d.handle(bad) {
                Response::Error(e) => assert_eq!(e.code, ErrorCode::NotFound),
                other => panic!("{other:?}"),
            }
        }
        // Per-entry WAIT resolves the span (times out: nothing dispatches),
        // and an unknown entry index is not_found.
        match d.handle(Request::WaitEntry {
            manifest: 1,
            entry: 0,
            timeout_secs: 0.0,
        }) {
            Response::Wait(w) => {
                assert!(w.timed_out);
                // One array job (8 tasks materialize into a single job).
                assert_eq!(w.requested, 1);
                assert_eq!(w.dispatched, 0);
            }
            other => panic!("{other:?}"),
        }
        match d.handle(Request::WaitEntry {
            manifest: 1,
            entry: 7,
            timeout_secs: 0.0,
        }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn recovery_replays_admissions_manifests_and_cancels() {
        let tmp = TempDir::new("spotcloud-daemon-recover");
        let cfg = DaemonConfig {
            speedup: 0.0,
            pacer_tick_ms: 1,
            durability: Some(DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Always)),
            ..DaemonConfig::default()
        };
        let (first_span, spot_id);
        {
            let d = daemon_with(cfg.clone());
            let m = ManifestBuilder::new()
                .interactive(1, JobType::Array, 8)
                .last(|e| e.with_tag("replayed"))
                .build();
            let ack = match d.handle(Request::MSubmit(m)) {
                Response::ManifestAck(a) => a,
                other => panic!("{other:?}"),
            };
            first_span = (ack.accepted[0].first, ack.accepted[0].count);
            let spot = match d.handle(Request::Submit(SubmitSpec::new(
                QosClass::Spot,
                JobType::Array,
                16,
                9,
            ))) {
                Response::SubmitAck(a) => a,
                other => panic!("{other:?}"),
            };
            spot_id = spot.first;
            match d.handle(Request::Scancel(spot_id)) {
                Response::Cancelled(id) => assert_eq!(id, spot_id),
                other => panic!("{other:?}"),
            }
            d.shutdown();
        }
        let (d, report) = Daemon::recover(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            cfg,
        )
        .expect("recovery");
        assert_eq!(report.admits_replayed, 2);
        assert_eq!(report.cancels_replayed, 1);
        assert_eq!(report.manifests_restored, 1);
        // The acked ids resolve to the same jobs after replay.
        match d.handle(Request::Sjob(first_span.0)) {
            Response::Job(detail) => assert_eq!(detail.qos, QosClass::Normal),
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Sjob(spot_id)) {
            Response::Job(detail) => assert_eq!(detail.state, JobState::Cancelled),
            other => panic!("{other:?}"),
        }
        // Resume-by-tag still resolves with the original id span.
        let info = match d.handle(Request::Resume(ResumeTarget::Tag("replayed".into()))) {
            Response::Resume(info) => info,
            other => panic!("{other:?}"),
        };
        assert_eq!(info.entries[0].first, first_span.0);
        assert_eq!(info.entries[0].count, first_span.1);
        // New submissions continue the id sequence — nothing is reused.
        match d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::Array,
            4,
            9,
        ))) {
            Response::SubmitAck(a) => assert_eq!(a.first, report.next_id),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn journal_append_failure_admits_nothing_and_degrades_to_read_only() {
        let tmp = TempDir::new("spotcloud-daemon-fault");
        let d = frozen_daemon_with_journal(faulty_durability(
            tmp.path(),
            FsyncPolicy::Always,
            crate::coordinator::FaultPoint::AfterAppend,
        ));
        match d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::Array,
            8,
            9,
        ))) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::ReadOnly),
            other => panic!("a journal fault must fail the admission: {other:?}"),
        }
        // The degradation is an observable state, not just per-request
        // errors: HEALTH pins at read_only and the poison count surfaces.
        let h = d.health_report();
        assert_eq!(h.state, HealthState::ReadOnly);
        assert_eq!(h.journal_poisoned, 1);
        // No probe outcome un-poisons a journal: ReadOnly is sticky.
        d.probe_health();
        assert_eq!(d.health_state(), HealthState::ReadOnly);
        // Write-ahead means no scheduler mutation happened.
        let snap = d.read_snapshot();
        assert_eq!(snap.pending + snap.running, 0, "nothing was admitted");
        // The poisoned journal keeps failing admissions (read-only daemon)
        // rather than silently dropping durability.
        match d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::Array,
            8,
            9,
        ))) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::ReadOnly),
            other => panic!("{other:?}"),
        }
        // Reads still serve.
        assert_eq!(d.handle(Request::Ping), Response::Pong);
    }

    // ---- overload control plane --------------------------------------------

    #[test]
    fn token_bucket_admits_burst_then_hints_retry() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, 2.0, t0);
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_ok());
        let retry = b.try_take(t0).expect_err("burst spent");
        // 10/s refill: the next token is ~100ms away.
        assert!((1..=200).contains(&retry), "{retry}");
        // After enough elapsed time the bucket admits again (and caps at
        // its burst: a long idle stretch does not bank unlimited tokens).
        let later = t0 + Duration::from_secs(10);
        assert!(b.try_take(later).is_ok());
        assert!(b.try_take(later).is_ok());
        assert!(b.try_take(later).is_err(), "capacity is the burst, not the idle time");
    }

    #[test]
    fn user_rate_limit_sheds_typed_and_isolates_users() {
        let d = daemon_with(DaemonConfig {
            speedup: 0.0,
            overload: OverloadConfig {
                user_rate: 0.001, // effectively one request per bucket lifetime
                user_burst: 1.0,
                ..OverloadConfig::default()
            },
            ..DaemonConfig::default()
        });
        let submit = |user| {
            d.handle_line_versioned(
                &format!("SUBMIT qos=spot type=array tasks=8 user={user}"),
                ProtocolVersion::V2,
            )
            .0
        };
        assert!(submit(9).starts_with("OK kind=submit_ack"), "burst token admits");
        let refused = submit(9);
        assert!(
            refused.starts_with("ERR code=overloaded retry_after_ms="),
            "second request exceeds user 9's bucket: {refused}"
        );
        // Another user's bucket is untouched: no cross-user starvation.
        assert!(submit(10).starts_with("OK kind=submit_ack"), "user 10 unaffected");
        assert_eq!(d.metrics.shed_rate_limited.load(Ordering::Relaxed), 1);
        // The shed event drives one probe to Shedding; a quiet probe
        // after it recovers to Healthy (bounded by one interval).
        d.probe_health();
        assert_eq!(d.health_state(), HealthState::Shedding);
        d.probe_health();
        assert_eq!(d.health_state(), HealthState::Healthy);
    }

    #[test]
    fn idle_user_buckets_are_retired_at_scale() {
        let d = daemon_with(DaemonConfig {
            speedup: 0.0,
            overload: OverloadConfig {
                // High refill: a bucket saturates within a microsecond of
                // its one admission, so the sweep can always retire it.
                user_rate: 1_000_000.0,
                user_burst: 4.0,
                ..OverloadConfig::default()
            },
            ..DaemonConfig::default()
        });
        // 100k distinct users, one admission each — the PR-9 map grew one
        // bucket per user forever; the watermark sweep now retires
        // refill-saturated buckets, so the map stays bounded far below the
        // user cardinality.
        for u in 0..100_000u32 {
            let admitted = d.admit_sheddable(Some(u), &d.metrics.shed_msubmits);
            assert!(admitted.is_ok(), "user {u} must admit on a fresh bucket");
        }
        let live = d.user_bucket_count();
        assert!(
            live <= USER_BUCKET_SWEEP_MIN * 2,
            "bucket map tracks ~active users, not all 100k seen: {live}"
        );
        assert_eq!(d.metrics.shed_rate_limited.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn bucket_sweep_is_lossless_and_hard_cap_evicts_oldest() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(2.0, 4.0, t0);
        assert!(b.is_saturated(t0), "fresh buckets start full");
        b.try_take(t0).unwrap();
        assert!(!b.is_saturated(t0), "one token out: mid-refill");
        assert!(
            b.is_saturated(t0 + Duration::from_secs(1)),
            "2 tokens/s re-fills the spent token well within a second"
        );
        // Hard-cap pressure: every bucket mid-refill, oldest evicted first.
        let mut map = FxHashMap::default();
        for u in 0..(USER_BUCKET_HARD_CAP as u32 + 10) {
            let at = t0 + Duration::from_nanos(u64::from(u));
            let mut bucket = TokenBucket::new(0.0001, 1.0, at);
            bucket.try_take(at).unwrap();
            map.insert(u, bucket);
        }
        Daemon::retire_idle_buckets(&mut map, t0 + Duration::from_millis(1));
        assert_eq!(map.len(), USER_BUCKET_HARD_CAP / 2);
        let newest = USER_BUCKET_HARD_CAP as u32 + 9;
        assert!(map.contains_key(&newest), "most recently touched survives");
        assert!(!map.contains_key(&0), "least recently touched goes first");
    }

    #[test]
    fn stats_exposes_user_scale_gauges() {
        let d = daemon();
        let (resp, _) = d.handle_line_versioned("STATS", ProtocolVersion::V2);
        assert!(resp.contains("users_active="), "{resp}");
        assert!(resp.contains("users_tracked="), "{resp}");
        assert!(resp.contains("buckets_live=0"), "{resp}");
        // v1 keeps its original key set byte-compatible.
        let (v1, _) = d.handle_line_versioned("STATS", ProtocolVersion::V1);
        assert!(!v1.contains("users_active="), "{v1}");
    }

    #[test]
    fn v3_binary_msubmit_frames_execute_and_interlock_with_chunk_streams() {
        let d = daemon();
        let m = ManifestBuilder::new()
            .interactive(1, JobType::Array, 8)
            .spot(9, JobType::Array, 64)
            .build();
        let payload = codec::render_msubmit_v3(&m);
        let frame = d.handle_msubmit_frame(codec::parse_msubmit_v3(&payload), None);
        let len = codec::decode_frame_header(&frame).unwrap().unwrap();
        assert_eq!(frame.len(), codec::FRAME_HEADER_BYTES + len);
        assert_eq!(frame[codec::FRAME_HEADER_BYTES], codec::OP_MANIFEST_ACK);
        let ack = codec::parse_manifest_ack_v3(&frame[codec::FRAME_HEADER_BYTES + 1..]).unwrap();
        assert_eq!(ack.accepted.len(), 2);
        assert_eq!(ack.jobs, 2);
        assert!(ack.manifest.is_some());
        // A wire-malformed payload answers with a typed ERR text frame on
        // the same connection — no desync, no close.
        let bad = d.handle_msubmit_frame(codec::parse_msubmit_v3(&[0x00]), None);
        assert_eq!(bad[codec::FRAME_HEADER_BYTES], codec::OP_TEXT_RESP);
        let body = std::str::from_utf8(&bad[codec::FRAME_HEADER_BYTES + 1..]).unwrap();
        assert!(body.starts_with("ERR code=bad_arg"), "{body}");
        // A binary MSUBMIT landing while a chunked text stream is open
        // discards the partial manifest, mirroring the text interlock.
        let mut asm = ChunkAssembler::new();
        let chunk = "MSUBMIT entries=2 part=1/2;qos=normal type=array tasks=4 user=1";
        match d.handle_line_stateful(chunk, ProtocolVersion::V3, Some(&mut asm)) {
            LineOutcome::Done(resp, _) => {
                assert!(resp.starts_with("OK kind=chunk_ack"), "{resp}")
            }
            LineOutcome::Parked(_) => panic!("chunk ack cannot park"),
        }
        let out = d.handle_msubmit_frame(codec::parse_msubmit_v3(&payload), Some(&mut asm));
        let body = std::str::from_utf8(&out[codec::FRAME_HEADER_BYTES + 1..]).unwrap();
        assert!(body.starts_with("ERR code=unsupported"), "{body}");
        assert!(!asm.in_progress(), "partial stream discarded");
    }

    #[test]
    fn hello_renegotiation_is_refused_inside_v3_frames() {
        let d = daemon();
        let (resp, negotiated) = d.handle_line_versioned("HELLO v2", ProtocolVersion::V3);
        assert!(resp.starts_with("ERR code=unsupported"), "{resp}");
        assert_eq!(negotiated, None);
        // Every other verb rides v3 text frames as plain v2.1 grammar.
        let (resp, _) = d.handle_line_versioned("PING", ProtocolVersion::V3);
        assert_eq!(resp, "OK kind=pong");
    }

    #[test]
    fn expired_deadline_drops_before_execution() {
        let d = daemon();
        // Fresh budget: executes normally.
        match d.handle_line_at("deadline_ms=60000 PING", ProtocolVersion::V2, None, Instant::now())
        {
            LineOutcome::Done(resp, _) => assert_eq!(resp, "OK kind=pong"),
            LineOutcome::Parked(_) => panic!("PING cannot park"),
        }
        // A budget already spent while queued: dropped typed, unexecuted.
        let arrived = Instant::now() - Duration::from_millis(50);
        let before = d.read_snapshot();
        match d.handle_line_at(
            "deadline_ms=10 SUBMIT qos=spot type=array tasks=8 user=9",
            ProtocolVersion::V2,
            None,
            arrived,
        ) {
            LineOutcome::Done(resp, _) => assert!(
                resp.starts_with("ERR code=overloaded retry_after_ms=0"),
                "{resp}"
            ),
            LineOutcome::Parked(_) => panic!("expired request cannot park"),
        }
        assert_eq!(d.metrics.deadline_expired.load(Ordering::Relaxed), 1);
        let after = d.read_snapshot();
        assert_eq!(
            after.pending + after.running,
            before.pending + before.running,
            "the expired SUBMIT never reached the scheduler"
        );
        // v1 has no deadline extension: the prefix is not stripped, the
        // line is simply not a v1 command.
        match d.handle_line_at(
            "deadline_ms=10 PING",
            ProtocolVersion::V1,
            None,
            Instant::now(),
        ) {
            LineOutcome::Done(resp, _) => assert!(resp.starts_with("ERR "), "{resp}"),
            LineOutcome::Parked(_) => panic!("cannot park"),
        }
    }

    #[test]
    fn health_verb_reports_state_and_stats_carry_the_block() {
        let d = daemon();
        let line = d.handle_line("HEALTH");
        assert!(line.starts_with("OK health state=healthy"), "{line}");
        match d.handle(Request::Health) {
            Response::Health(h) => {
                assert_eq!(h.state, HealthState::Healthy);
                assert_eq!(h.inflight, 0);
            }
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Stats) {
            Response::Stats(s) => {
                let h = s.health.expect("stats embed the health block");
                assert_eq!(h.state, HealthState::Healthy);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn pruned_ids_keep_their_typed_semantics_across_recovery() {
        // Satellite regression: history_cap pruning + event-log pruning must
        // compose with journal checkpoint-truncation — a daemon that pruned,
        // checkpointed, crashed, and recovered answers SJOB/WAIT on
        // pre-crash ids exactly like one that never crashed.
        let tmp = TempDir::new("spotcloud-daemon-prune-recover");
        let cfg = DaemonConfig {
            speedup: 10_000.0,
            pacer_tick_ms: 1,
            retire_grace_secs: Some(2.0),
            history_cap: Some(2),
            durability: Some(
                DurabilityConfig::new(tmp.path())
                    .with_fsync(FsyncPolicy::Never)
                    .with_checkpoint_every(1),
            ),
            ..DaemonConfig::default()
        };
        let mut ids = Vec::new();
        {
            let d = daemon_with(cfg.clone());
            for run in [1.0, 2.0, 3.0] {
                let ack = match d.handle(Request::Submit(
                    SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1)
                        .with_run_secs(run),
                )) {
                    Response::SubmitAck(a) => a,
                    other => panic!("{other:?}"),
                };
                let wait = match d.handle(Request::Wait {
                    jobs: vec![ack.first],
                    timeout_secs: 10.0,
                }) {
                    Response::Wait(w) => w,
                    other => panic!("{other:?}"),
                };
                assert!(!wait.timed_out);
                ids.push(ack.first);
            }
            // Pace until all three retired (and the cap pruned the oldest).
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                d.pace();
                let snap = d.read_snapshot();
                if ids.iter().all(|&id| snap.job(id).is_none()) {
                    break;
                }
                assert!(Instant::now() < deadline, "jobs were never retired");
                std::thread::sleep(Duration::from_millis(2));
            }
            // One more admission checkpoints the pruned state into the
            // journal (checkpoint_every = 1).
            match d.handle(Request::Submit(SubmitSpec::new(
                QosClass::Spot,
                JobType::Array,
                8,
                9,
            ))) {
                Response::SubmitAck(_) => {}
                other => panic!("{other:?}"),
            }
            d.shutdown();
        }
        let (d, report) = Daemon::recover(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            cfg,
        )
        .expect("recovery");
        assert!(report.history_restored <= 2, "{report}");
        // The pruned id is the same typed not_found as before the crash…
        match d.handle(Request::Sjob(ids[0])) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::NotFound),
            other => panic!("pruned id must stay not_found after recovery: {other:?}"),
        }
        match d.handle(Request::Wait {
            jobs: vec![ids[0]],
            timeout_secs: 1.0,
        }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
        // …and the retained history ids still answer, exactly once, with
        // their settled pre-crash state.
        match d.handle(Request::Sjob(ids[2])) {
            Response::Job(detail) => assert_eq!(detail.state, JobState::Completed),
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Wait {
            jobs: vec![ids[2]],
            timeout_secs: 1.0,
        }) {
            Response::Wait(w) => {
                assert!(!w.timed_out, "settled history job must not re-wait");
                assert_eq!(w.dispatched, 1);
            }
            other => panic!("{other:?}"),
        }
    }

    // ---- scheduler sharding -----------------------------------------------

    /// A two-shard daemon with a frozen clock (admission-focused tests:
    /// nothing dispatches until `pace` runs at speedup > 0).
    fn sharded_daemon(speedup: f64) -> Arc<Daemon> {
        daemon_with(DaemonConfig {
            speedup,
            pacer_tick_ms: 1,
            shard_count: 2,
            ..DaemonConfig::default()
        })
    }

    #[test]
    fn sharded_daemon_routes_by_qos_and_merges_the_read_view() {
        let d = sharded_daemon(0.0);
        assert_eq!(d.shard_count(), 2);
        let a = match d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Normal,
            JobType::Array,
            8,
            1,
        ))) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        let b = match d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::Array,
            16,
            9,
        ))) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        // Global ids: unique and allocator-ordered across shards.
        assert_eq!((a.first, b.first), (1, 2));
        // Each job lives on exactly its partition's shard…
        assert_eq!(d.with_shard(0, |s| s.jobs().count()), 1, "interactive shard");
        assert_eq!(d.with_shard(1, |s| s.jobs().count()), 1, "spot shard");
        d.with_shard(0, |s| assert!(s.job(JobId(1)).is_some()));
        d.with_shard(1, |s| assert!(s.job(JobId(2)).is_some()));
        // …while the merged read view shows both, shard-blind.
        let snap = d.read_snapshot();
        assert!(snap.job(1).is_some() && snap.job(2).is_some());
        assert_eq!(snap.pending, 2);
        match d.handle(Request::Squeue(SqueueFilter::default())) {
            Response::Jobs(rows) => assert_eq!(rows.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sharded_msubmit_spans_partitions_with_contiguous_ids() {
        let d = sharded_daemon(0.0);
        // Interactive / spot / interactive: three runs across two shards.
        let m = ManifestBuilder::new()
            .interactive(1, JobType::Array, 8)
            .spot(9, JobType::Array, 64)
            .last(|e| e.with_count(2))
            .interactive(2, JobType::Individual, 3)
            .build();
        let ack = match d.handle(Request::MSubmit(m)) {
            Response::ManifestAck(a) => a,
            other => panic!("{other:?}"),
        };
        assert_eq!(ack.rejected.len(), 0);
        assert_eq!(ack.jobs, 1 + 2 + 3);
        // One contiguous global range, ascending in manifest order.
        assert_eq!(ack.job_ids(), vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(ack.entry(0).unwrap().first, 1);
        assert_eq!(ack.entry(1).unwrap().ids().collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(ack.entry(2).unwrap().first, 4);
        // Jobs landed on their partitions' shards, invariants intact.
        assert_eq!(d.with_shard(0, |s| s.jobs().count()), 4);
        assert_eq!(d.with_shard(1, |s| s.jobs().count()), 2);
        for idx in 0..2 {
            d.with_shard(idx, |s| s.check_invariants().expect("shard invariants"));
        }
        // The registry resolves entries for RESUME / per-entry WAIT.
        match d.handle(Request::Resume(ResumeTarget::Manifest(ack.manifest.unwrap()))) {
            Response::Resume(info) => assert_eq!(info.entries.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sharded_scancel_finds_the_owning_shard() {
        let d = sharded_daemon(0.0);
        d.handle(Request::Submit(SubmitSpec::new(QosClass::Normal, JobType::Array, 8, 1)));
        d.handle(Request::Submit(SubmitSpec::new(QosClass::Spot, JobType::Array, 16, 9)));
        // The spot job lives on shard 1 — the probe must find it there.
        match d.handle(Request::Scancel(2)) {
            Response::Cancelled(2) => {}
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Sjob(2)) {
            Response::Job(detail) => assert_eq!(detail.state, JobState::Cancelled),
            other => panic!("{other:?}"),
        }
        // Unknown ids stay typed not_found after probing every shard.
        match d.handle(Request::Scancel(99)) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
        for idx in 0..2 {
            d.with_shard(idx, |s| s.check_invariants().expect("shard invariants"));
        }
    }

    #[test]
    fn sharded_wait_resolves_across_shards_exactly_once() {
        // Real pacing: the spot job dispatches on shard 1 while the WAIT
        // entered through the shard-agnostic typed path.
        let d = sharded_daemon(10_000.0);
        let ack = match d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::Array,
            16,
            9,
        ))) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 10.0,
        }) {
            Response::Wait(w) => {
                assert!(!w.timed_out, "spot dispatch must resolve the wait");
                assert_eq!(w.dispatched, 1);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(d.metrics.waits_resumed.load(Ordering::Relaxed), 1, "exactly once");
    }

    #[test]
    fn sharded_stats_and_util_expose_shard_rows() {
        let d = sharded_daemon(0.0);
        d.handle(Request::Submit(SubmitSpec::new(QosClass::Spot, JobType::Array, 16, 9)));
        let stats = match d.handle(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        let sched_rows: Vec<_> = stats
            .shards
            .iter()
            .filter(|s| s.kind == ShardKind::Sched)
            .collect();
        assert_eq!(sched_rows.len(), 2);
        assert_eq!(sched_rows[0].label, "interactive");
        assert_eq!(sched_rows[1].label, "spot");
        assert_eq!(sched_rows[1].queue_depth, 1, "spot queue depth from its slot");
        assert!(sched_rows[1].wakeups >= 1, "submit locked the spot shard");
        let util = match d.handle(Request::Util) {
            Response::Util(u) => u,
            other => panic!("{other:?}"),
        };
        assert_eq!(util.shards.len(), 2);
        assert_eq!(
            util.shards.iter().map(|s| s.total_cores).sum::<u32>(),
            util.total_cores,
            "shard slices cover the whole pool"
        );
        assert_eq!(util.shards[1].pending, 1);
        // The unsharded daemon keeps the v1-compatible empty shape.
        let d1 = daemon();
        match d1.handle(Request::Util) {
            Response::Util(u) => assert!(u.shards.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn durability_with_shards_boots_per_shard_journals() {
        let tmp = TempDir::new("shards-durability");
        let cfg = DaemonConfig {
            speedup: 0.0,
            shard_count: 2,
            durability: Some(DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Never)),
            ..DaemonConfig::default()
        };
        let (normal_id, spot_id, mid);
        {
            let d = daemon_with(cfg.clone());
            assert_eq!(d.shard_count(), 2);
            // The sharded layout exists on disk: alloc log + shard dirs.
            assert!(crate::coordinator::journal::dir_has_shard_layout(tmp.path()));
            assert_eq!(
                crate::coordinator::journal::list_shard_dirs(tmp.path()).len(),
                2
            );
            let ack = match d.handle(Request::Submit(SubmitSpec::new(
                QosClass::Normal,
                JobType::Array,
                8,
                1,
            ))) {
                Response::SubmitAck(a) => a,
                other => panic!("{other:?}"),
            };
            normal_id = ack.first;
            let ack = match d.handle(Request::Submit(SubmitSpec::new(
                QosClass::Spot,
                JobType::Array,
                16,
                9,
            ))) {
                Response::SubmitAck(a) => a,
                other => panic!("{other:?}"),
            };
            spot_id = ack.first;
            // A cross-shard manifest: one interactive + one spot entry.
            let m = ManifestBuilder::new()
                .interactive(2, JobType::Array, 8)
                .last(|e| e.with_tag("xshard"))
                .spot(9, JobType::Array, 32)
                .build();
            let mack = match d.handle(Request::MSubmit(m)) {
                Response::ManifestAck(a) => a,
                other => panic!("{other:?}"),
            };
            mid = mack.manifest.expect("manifest id");
            match d.handle(Request::Scancel(spot_id)) {
                Response::Cancelled(id) => assert_eq!(id, spot_id),
                other => panic!("{other:?}"),
            }
            match d.handle(Request::Stats) {
                Response::Stats(s) => {
                    let j = s.journal.expect("journaling daemon reports journal stats");
                    assert!(j.appends >= 4, "two submits + two manifest parts: {j:?}");
                    assert_eq!(j.poisoned, 0);
                }
                other => panic!("{other:?}"),
            }
            d.shutdown();
        }
        // Kill (drop) and recover at the same shard count: acked ids are
        // identical, cross-shard manifest intact, cancel replayed.
        let (d, report) = Daemon::recover(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            cfg,
        )
        .expect("sharded recovery");
        assert_eq!(d.shard_count(), 2);
        assert!(report.admits_replayed >= 1, "{report}");
        assert_eq!(report.leases_skipped_torn, 0);
        match d.handle(Request::Sjob(normal_id)) {
            Response::Job(detail) => assert_eq!(detail.qos, QosClass::Normal),
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Sjob(spot_id)) {
            Response::Job(detail) => assert_eq!(detail.state, JobState::Cancelled),
            other => panic!("{other:?}"),
        }
        let info = match d.handle(Request::Resume(ResumeTarget::Manifest(mid))) {
            Response::Resume(info) => info,
            other => panic!("{other:?}"),
        };
        assert_eq!(info.entries.len(), 2, "cross-shard manifest survived whole");
        for idx in 0..2 {
            d.with_shard(idx, |s| s.check_invariants().expect("shard invariants"));
        }
    }

    #[test]
    fn try_new_refuses_existing_journal_state_typed() {
        let tmp = TempDir::new("config-error-exists");
        let cfg = DaemonConfig {
            speedup: 0.0,
            durability: Some(DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Never)),
            ..DaemonConfig::default()
        };
        {
            let d = daemon_with(cfg.clone());
            d.handle(Request::Submit(SubmitSpec::new(QosClass::Spot, JobType::Array, 8, 9)));
            d.shutdown();
        }
        // A fresh boot over live journal state is a typed refusal, not a
        // silent shadow (and `new` still panics for embedders).
        match Daemon::try_new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            cfg.clone(),
        ) {
            Err(ConfigError::JournalExists(dir)) => assert_eq!(dir, tmp.path()),
            other => panic!("expected JournalExists: {:?}", other.err()),
        }
        // Same refusal for the sharded layout.
        let tmp2 = TempDir::new("config-error-exists-sharded");
        let cfg2 = DaemonConfig {
            speedup: 0.0,
            shard_count: 2,
            durability: Some(DurabilityConfig::new(tmp2.path()).with_fsync(FsyncPolicy::Never)),
            ..DaemonConfig::default()
        };
        {
            daemon_with(cfg2.clone()).shutdown();
        }
        assert!(matches!(
            Daemon::try_new(
                topology::tx2500(),
                SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
                cfg2,
            ),
            Err(ConfigError::JournalExists(_))
        ));
    }

    #[test]
    fn recover_refuses_layout_mismatch_typed() {
        // Flat journal written by a single-shard daemon, recovered with a
        // sharded boot config: a typed ShardLayoutMismatch, never a guess.
        let tmp = TempDir::new("config-error-layout");
        let flat = DaemonConfig {
            speedup: 0.0,
            durability: Some(DurabilityConfig::new(tmp.path()).with_fsync(FsyncPolicy::Never)),
            ..DaemonConfig::default()
        };
        {
            let d = daemon_with(flat.clone());
            d.handle(Request::Submit(SubmitSpec::new(QosClass::Spot, JobType::Array, 8, 9)));
            d.shutdown();
        }
        let sharded_boot = DaemonConfig {
            shard_count: 2,
            ..flat.clone()
        };
        match Daemon::recover(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            sharded_boot,
        ) {
            Err(RecoveryError::Config(ConfigError::ShardLayoutMismatch { .. })) => {}
            other => panic!("expected ShardLayoutMismatch: {:?}", other.err()),
        }
        // And the converse: a sharded journal with a single-shard boot.
        let tmp2 = TempDir::new("config-error-layout-rev");
        let sharded = DaemonConfig {
            speedup: 0.0,
            shard_count: 2,
            durability: Some(DurabilityConfig::new(tmp2.path()).with_fsync(FsyncPolicy::Never)),
            ..DaemonConfig::default()
        };
        {
            daemon_with(sharded.clone()).shutdown();
        }
        let single_boot = DaemonConfig {
            shard_count: 1,
            ..sharded
        };
        match Daemon::recover(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            single_boot,
        ) {
            Err(RecoveryError::Config(ConfigError::ShardLayoutMismatch { .. })) => {}
            other => panic!("expected ShardLayoutMismatch: {:?}", other.err()),
        }
    }

    #[test]
    fn group_commit_ack_waits_for_a_covering_sync() {
        // fsync=always with group commit on: concurrent submits batch
        // into shared fsyncs, every ack is durable, and the group-commit
        // counters move.
        let tmp = TempDir::new("group-commit-daemon");
        let cfg = DaemonConfig {
            speedup: 0.0,
            durability: Some(
                DurabilityConfig::new(tmp.path())
                    .with_fsync(FsyncPolicy::Always)
                    .with_group_commit(true),
            ),
            ..DaemonConfig::default()
        };
        let d = daemon_with(cfg.clone());
        let threads: Vec<_> = (0..4)
            .map(|u| {
                let d = Arc::clone(&d);
                std::thread::spawn(move || {
                    for _ in 0..8 {
                        match d.handle(Request::Submit(SubmitSpec::new(
                            QosClass::Spot,
                            JobType::Array,
                            8,
                            u,
                        ))) {
                            Response::SubmitAck(_) => {}
                            other => panic!("{other:?}"),
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().expect("submitter");
        }
        let stats = match d.handle(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        let j = stats.journal.expect("journal stats");
        assert_eq!(j.appends, 32);
        assert_eq!(j.synced_appends, 32, "every ack waited for a covering sync");
        assert!(
            j.group_commits >= 1 && j.group_commits <= 32,
            "syncs batched: {}",
            j.group_commits
        );
        d.shutdown();
        drop(d);
        // Every acked admission is on disk.
        let (d, report) = Daemon::recover(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            cfg,
        )
        .expect("recovery");
        assert_eq!(report.admits_replayed, 32);
        d.with_scheduler(|s| assert_eq!(s.jobs().count(), 32));
    }

    #[test]
    fn single_part_chunk_admits_through_the_typed_path() {
        use crate::coordinator::manifest::ManifestChunk;
        let d = daemon();
        let chunk = ManifestChunk {
            entries: 2,
            part: 1,
            parts: 1,
            records: vec![
                ManifestEntry::new(QosClass::Normal, JobType::Array, 8, 1),
                ManifestEntry::new(QosClass::Spot, JobType::Array, 16, 9),
            ],
        };
        match d.handle(Request::MSubmitChunk(chunk)) {
            Response::ManifestAck(a) => {
                assert_eq!(a.accepted.len(), 2);
                assert_eq!(a.jobs, 2);
            }
            other => panic!("{other:?}"),
        }
        // A multi-part chunk cannot be assembled without a connection.
        let partial = ManifestChunk {
            entries: 4,
            part: 1,
            parts: 2,
            records: vec![ManifestEntry::new(QosClass::Spot, JobType::Array, 8, 9)],
        };
        match d.handle(Request::MSubmitChunk(partial)) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::Unsupported),
            other => panic!("{other:?}"),
        }
    }
}
