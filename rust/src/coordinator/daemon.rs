//! The daemon core: the scheduler as a long-running, thread-safe service.
//!
//! Virtual time advances against the wall clock via a **pacer** thread: every
//! tick it runs the scheduler's event loop up to `elapsed_wall × speedup`.
//! API requests (submit, queue, cancel, stats) lock the scheduler, act, and
//! return. Interactive jobs' virtual scheduling latencies (the paper's
//! metric) are harvested from the event log into the daemon metrics.
//!
//! The daemon works entirely in the typed protocol: [`Daemon::handle`] is
//! `fn(&self, Request) -> Response`; wire rendering lives in
//! [`super::codec`] and is reached through [`Daemon::handle_line_versioned`].

use super::api::{
    ApiError, JobDetail, JobSummary, ProtocolVersion, Request, Response, SqueueFilter,
    StatsSnapshot, SubmitAck, SubmitSpec, UtilSnapshot, WaitResult,
};
use super::codec;
use super::metrics::DaemonMetrics;
use crate::cluster::Cluster;
use crate::job::{JobId, JobSpec, JobState, QosClass, UserId};
use crate::sched::{LogKind, Scheduler, SchedulerConfig};
use crate::sim::SimTime;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on jobs created by one batched `SUBMIT` (keeps a typo'd
/// `count=` from allocating unbounded scheduler state in one RPC).
pub const MAX_BATCH_JOBS: u64 = 1_000_000;

/// Upper bound on a `WAIT` timeout (wall seconds).
pub const MAX_WAIT_SECS: f64 = 3600.0;

/// Daemon parameters.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Virtual seconds advanced per wall-clock second (the simulation keeps
    /// up with real submissions at any speedup; 1.0 = real time).
    pub speedup: f64,
    /// Pacer tick in milliseconds.
    pub pacer_tick_ms: u64,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            speedup: 60.0,
            pacer_tick_ms: 5,
        }
    }
}

/// The daemon: shared scheduler + metrics + lifecycle flag.
pub struct Daemon {
    sched: Mutex<Scheduler>,
    /// Daemon metrics (public for the e2e driver's reporting).
    pub metrics: DaemonMetrics,
    running: AtomicBool,
    start: Instant,
    cfg: DaemonConfig,
    tracked: Mutex<BTreeSet<JobId>>,
}

impl Daemon {
    /// Create a daemon over a fresh scheduler.
    pub fn new(cluster: Cluster, sched_cfg: SchedulerConfig, cfg: DaemonConfig) -> Arc<Self> {
        Arc::new(Self {
            sched: Mutex::new(Scheduler::new(cluster, sched_cfg)),
            metrics: DaemonMetrics::default(),
            running: AtomicBool::new(true),
            start: Instant::now(),
            cfg,
            tracked: Mutex::new(BTreeSet::new()),
        })
    }

    /// Still serving?
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    /// Request shutdown.
    pub fn shutdown(&self) {
        self.running.store(false, Ordering::SeqCst);
    }

    /// Target virtual time for the current wall clock.
    fn target_now(&self) -> SimTime {
        SimTime::from_secs_f64(self.start.elapsed().as_secs_f64() * self.cfg.speedup)
    }

    /// Advance the scheduler to the current wall-paced virtual time and
    /// harvest newly dispatched tracked jobs into the metrics.
    pub fn pace(&self) {
        let target = self.target_now();
        let mut sched = self.sched.lock().expect("scheduler poisoned");
        if target > sched.now() {
            sched.run_until(target);
        }
        let mut tracked = self.tracked.lock().expect("tracked poisoned");
        let done: Vec<JobId> = tracked
            .iter()
            .copied()
            .filter(|&j| sched.log().last(j, LogKind::DispatchDone).is_some())
            .collect();
        for j in done {
            tracked.remove(&j);
            let rec = sched.log().first(j, LogKind::Recognized).expect("recognized");
            let dis = sched.log().last(j, LogKind::DispatchDone).expect("dispatched");
            self.metrics.record_sched_latency(dis.saturating_sub(rec).as_nanos());
        }
    }

    /// Spawn the pacer thread. Returns its join handle; the thread exits on
    /// shutdown.
    pub fn spawn_pacer(self: &Arc<Self>) -> std::thread::JoinHandle<()> {
        let daemon = Arc::clone(self);
        std::thread::Builder::new()
            .name("spotcloud-pacer".into())
            .spawn(move || {
                while daemon.is_running() {
                    daemon.pace();
                    std::thread::sleep(std::time::Duration::from_millis(daemon.cfg.pacer_tick_ms));
                }
            })
            .expect("spawning pacer")
    }

    /// Handle one v1 request line; returns the rendered response body.
    /// (Compatibility surface — the transport uses
    /// [`Daemon::handle_line_versioned`].)
    pub fn handle_line(&self, line: &str) -> String {
        self.handle_line_versioned(line, ProtocolVersion::V1).0
    }

    /// Handle one request line under `version`. Returns the rendered
    /// response and, for a successful `HELLO`, the version the connection
    /// speaks from the next request on (the `HELLO` response itself is
    /// already rendered in the negotiated version).
    pub fn handle_line_versioned(
        &self,
        line: &str,
        version: ProtocolVersion,
    ) -> (String, Option<ProtocolVersion>) {
        let t0 = Instant::now();
        let (resp, render_version, negotiated) = match codec::parse_request(line, version) {
            Ok(req) => {
                self.metrics.record_command(req.command_name());
                let negotiated = match &req {
                    Request::Hello(v) => Some(*v),
                    _ => None,
                };
                let resp = self.handle(req);
                (resp, negotiated.unwrap_or(version), negotiated)
            }
            Err(e) => (Response::Error(e), version, None),
        };
        let ok = !matches!(resp, Response::Error(_));
        self.metrics.record_request(ok, t0.elapsed().as_nanos() as u64);
        (codec::render_response(&resp, render_version), negotiated)
    }

    /// Handle one typed request. Total: failures come back as
    /// [`Response::Error`].
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Hello(v) => Response::Hello(v),
            Request::Shutdown => {
                self.shutdown();
                Response::ShuttingDown
            }
            Request::Submit(spec) => self.handle_submit(&spec),
            Request::Scancel(id) => {
                let mut sched = self.sched.lock().expect("scheduler poisoned");
                if sched.cancel(JobId(id)) {
                    Response::Cancelled(id)
                } else {
                    Response::Error(ApiError::not_found(format!("unknown or finished job {id}")))
                }
            }
            Request::Squeue(filter) => self.handle_squeue(&filter),
            Request::Sjob(id) => self.handle_sjob(id),
            Request::Wait { jobs, timeout_secs } => self.handle_wait(&jobs, timeout_secs),
            Request::Stats => Response::Stats(self.stats_snapshot()),
            Request::Util => Response::Util(self.util_snapshot()),
        }
    }

    /// Materialize the specs a submission creates: `count` repetitions of
    /// the paper's per-type expansion (individual → one spec per task).
    fn materialize(spec: &SubmitSpec) -> Vec<JobSpec> {
        let mut specs = Vec::new();
        for _ in 0..spec.count {
            let batch = match spec.qos {
                QosClass::Normal => crate::workload::interactive_burst(
                    UserId(spec.user),
                    spec.job_type,
                    spec.tasks,
                ),
                QosClass::Spot => vec![JobSpec::spot(UserId(spec.user), spec.job_type, spec.tasks)],
            };
            specs.extend(
                batch
                    .into_iter()
                    .map(|s| s.with_run_time(SimTime::from_secs_f64(spec.run_secs))),
            );
        }
        specs
    }

    fn handle_submit(&self, spec: &SubmitSpec) -> Response {
        let expansion = match spec.qos {
            // Individual submissions expand to one job per task.
            QosClass::Normal if spec.job_type == crate::job::JobType::Individual => {
                spec.tasks as u64
            }
            _ => 1,
        };
        if spec.count as u64 * expansion > MAX_BATCH_JOBS {
            return Response::Error(ApiError::bad_arg(
                "count",
                &format!("{} (batch exceeds {MAX_BATCH_JOBS} jobs)", spec.count),
            ));
        }
        let specs = Self::materialize(spec);

        let mut sched = self.sched.lock().expect("scheduler poisoned");
        // Keep the virtual clock caught up so submissions land "now".
        let target = self.target_now();
        if target > sched.now() {
            sched.run_until(target);
        }
        let ids = if spec.count > 1 {
            // Batched: the whole burst arrives in this one RPC.
            sched.submit_batch(specs)
        } else {
            // Single spec: client-side serialization, as the paper's
            // launcher loop submits (one submit RPC apart).
            sched.submit_burst(specs)
        };
        self.metrics
            .jobs_submitted
            .fetch_add(ids.len() as u64, Ordering::Relaxed);
        if spec.qos == QosClass::Normal {
            let mut tracked = self.tracked.lock().expect("tracked poisoned");
            tracked.extend(ids.iter().copied());
        }
        let first = ids.first().map(|j| j.0).unwrap_or(0);
        let last = ids.last().map(|j| j.0).unwrap_or(0);
        Response::SubmitAck(SubmitAck {
            first,
            last,
            count: ids.len() as u64,
        })
    }

    fn handle_squeue(&self, filter: &SqueueFilter) -> Response {
        let sched = self.sched.lock().expect("scheduler poisoned");
        let states: Vec<JobState> = match filter.state {
            Some(s) => vec![s],
            None => vec![JobState::Pending, JobState::Running, JobState::Requeued],
        };
        let limit = filter.limit.unwrap_or(usize::MAX);
        let mut rows = Vec::new();
        'outer: for st in states {
            for id in sched.jobs_in_state(st) {
                let j = sched.job(id).expect("listed job");
                if filter.user.is_some_and(|u| j.spec.user.0 != u) {
                    continue;
                }
                if filter.qos.is_some_and(|q| j.spec.qos != q) {
                    continue;
                }
                rows.push(JobSummary {
                    id: id.0,
                    job_type: j.spec.job_type,
                    tasks: j.spec.tasks,
                    user: j.spec.user.0,
                    qos: j.spec.qos,
                    state: j.state,
                });
                if rows.len() >= limit {
                    break 'outer;
                }
            }
        }
        Response::Jobs(rows)
    }

    fn handle_sjob(&self, id: u64) -> Response {
        let sched = self.sched.lock().expect("scheduler poisoned");
        let Some(j) = sched.job(JobId(id)) else {
            return Response::Error(ApiError::not_found(format!("unknown job {id}")));
        };
        let recognized = sched.log().first(JobId(id), LogKind::Recognized);
        let dispatched = sched.log().last(JobId(id), LogKind::DispatchDone);
        let latency_ns = match (recognized, dispatched) {
            (Some(r), Some(d)) => Some(d.saturating_sub(r).as_nanos()),
            _ => None,
        };
        Response::Job(JobDetail {
            id,
            job_type: j.spec.job_type,
            tasks: j.spec.tasks,
            user: j.spec.user.0,
            qos: j.spec.qos,
            state: j.state,
            submit_secs: j.submit_time.as_secs_f64(),
            queue_secs: j.queue_time.as_secs_f64(),
            start_secs: j.start_time.map(SimTime::as_secs_f64),
            end_secs: j.end_time.map(SimTime::as_secs_f64),
            requeues: j.requeue_count,
            recognized_secs: recognized.map(SimTime::as_secs_f64),
            dispatched_secs: dispatched.map(SimTime::as_secs_f64),
            latency_ns,
        })
    }

    /// Block until every job in `jobs` has a `DispatchDone` log record, a
    /// terminal state makes dispatch impossible, or the wall timeout
    /// expires. Paces the scheduler itself, so it works with or without the
    /// pacer thread. Reports the burst's virtual scheduling latency (first
    /// `Recognized` → last `DispatchDone`), the paper's Figure-2 metric.
    fn handle_wait(&self, jobs: &[u64], timeout_secs: f64) -> Response {
        if jobs.is_empty() {
            return Response::Error(ApiError::bad_arg("jobs", "(empty)"));
        }
        if !(timeout_secs.is_finite() && (0.0..=MAX_WAIT_SECS).contains(&timeout_secs)) {
            return Response::Error(ApiError::bad_arg("timeout", &format!("{timeout_secs}")));
        }
        let ids: Vec<JobId> = jobs.iter().map(|&j| JobId(j)).collect();
        {
            let sched = self.sched.lock().expect("scheduler poisoned");
            for &id in &ids {
                if sched.job(id).is_none() {
                    return Response::Error(ApiError::not_found(format!("unknown job {}", id.0)));
                }
            }
        }
        let deadline = Instant::now() + Duration::from_secs_f64(timeout_secs);
        loop {
            self.pace();
            let mut timed_out = false;
            {
                let sched = self.sched.lock().expect("scheduler poisoned");
                let dispatched = ids
                    .iter()
                    .filter(|&&id| sched.log().last(id, LogKind::DispatchDone).is_some())
                    .count();
                // A job that reached a terminal state without ever
                // dispatching (e.g. cancelled while pending) can never
                // dispatch: don't hold the client hostage for it.
                let settled = ids.iter().all(|&id| {
                    sched.log().last(id, LogKind::DispatchDone).is_some()
                        || sched.job(id).map_or(true, |j| j.state.is_terminal())
                });
                if settled || Instant::now() >= deadline {
                    if !settled {
                        timed_out = true;
                    }
                    let latency_ns = sched
                        .log()
                        .measure(&ids)
                        .map(|m| {
                            m.last_dispatched
                                .saturating_sub(m.first_recognized)
                                .as_nanos()
                        })
                        .unwrap_or(0);
                    return Response::Wait(WaitResult {
                        requested: ids.len() as u32,
                        dispatched: dispatched as u32,
                        timed_out,
                        latency_ns,
                    });
                }
            }
            if !self.is_running() {
                return Response::Error(ApiError::unsupported("daemon is shutting down"));
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    fn stats_snapshot(&self) -> StatsSnapshot {
        let sched = self.sched.lock().expect("scheduler poisoned");
        let st = sched.stats();
        let hist = self.metrics.sched_latency();
        StatsSnapshot {
            virtual_now_secs: sched.now().as_secs_f64(),
            dispatches: st.dispatches,
            preemptions: st.preemptions,
            requeues: st.requeues,
            cron_passes: st.cron_passes,
            main_passes: st.main_passes,
            backfill_passes: st.backfill_passes,
            triggered_passes: st.triggered_passes,
            score_batches: st.score_batches,
            jobs_scored: st.jobs_scored,
            scorer: sched.config().scorer.name().to_string(),
            requests_ok: self.metrics.requests_ok.load(Ordering::Relaxed),
            requests_err: self.metrics.requests_err.load(Ordering::Relaxed),
            jobs_submitted: self.metrics.jobs_submitted.load(Ordering::Relaxed),
            sched_latency_count: hist.count(),
            sched_latency_p50_ns: hist.p50(),
            commands: self
                .metrics
                .command_counts()
                .into_iter()
                .map(|(cmd, n)| (cmd.to_ascii_lowercase(), n))
                .collect(),
        }
    }

    fn util_snapshot(&self) -> UtilSnapshot {
        let sched = self.sched.lock().expect("scheduler poisoned");
        let c = sched.cluster();
        UtilSnapshot {
            utilization: c.utilization(),
            idle_cores: c.idle_cores(),
            idle_nodes: c.idle_node_count(),
            total_cores: c.total_cores(),
            pending: sched.jobs_in_state(JobState::Pending).len(),
            running: sched.jobs_in_state(JobState::Running).len(),
        }
    }

    /// Lock and inspect the scheduler (tests + e2e reporting).
    pub fn with_scheduler<T>(&self, f: impl FnOnce(&Scheduler) -> T) -> T {
        let sched = self.sched.lock().expect("scheduler poisoned");
        f(&sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, PartitionLayout};
    use crate::job::JobType;
    use crate::sim::SchedCosts;

    fn daemon() -> Arc<Daemon> {
        Daemon::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
            DaemonConfig {
                speedup: 10_000.0, // tests shouldn't wait on the wall clock
                pacer_tick_ms: 1,
            },
        )
    }

    #[test]
    fn ping_and_stats() {
        let d = daemon();
        assert_eq!(d.handle_line("PING"), "OK pong");
        assert!(d.handle_line("STATS").contains("virtual_now"));
        // Typed path.
        assert_eq!(d.handle(Request::Ping), Response::Pong);
        match d.handle(Request::Stats) {
            Response::Stats(s) => assert_eq!(s.scorer, "native"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn submit_runs_to_dispatch() {
        let d = daemon();
        let resp = d.handle_line("SUBMIT normal triple 608 1 60");
        assert!(resp.starts_with("OK jobs="), "{resp}");
        // Pace until dispatch shows up in metrics.
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        while d.metrics.sched_latency().count() == 0 {
            assert!(Instant::now() < deadline, "job never dispatched");
            d.pace();
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let h = d.metrics.sched_latency();
        assert_eq!(h.count(), 1);
        // Baseline triple-mode latency is sub-second of *virtual* time.
        assert!(h.max() < 2_000_000_000, "virtual latency {}ns", h.max());
    }

    #[test]
    fn squeue_lists_jobs() {
        let d = daemon();
        d.handle_line("SUBMIT spot triple 320 9 600");
        let out = d.handle_line("SQUEUE");
        assert!(out.contains("triple-mode 320 user9 spot"), "{out}");
    }

    #[test]
    fn squeue_filters_apply() {
        let d = daemon();
        d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Spot,
            JobType::TripleMode,
            320,
            9,
        )));
        d.handle(Request::Submit(SubmitSpec::new(
            QosClass::Normal,
            JobType::Array,
            16,
            1,
        )));
        let all = match d.handle(Request::Squeue(SqueueFilter::default())) {
            Response::Jobs(rows) => rows,
            other => panic!("{other:?}"),
        };
        assert_eq!(all.len(), 2);
        let spot_only = match d.handle(Request::Squeue(SqueueFilter {
            qos: Some(QosClass::Spot),
            ..Default::default()
        })) {
            Response::Jobs(rows) => rows,
            other => panic!("{other:?}"),
        };
        assert_eq!(spot_only.len(), 1);
        assert_eq!(spot_only[0].user, 9);
        let limited = match d.handle(Request::Squeue(SqueueFilter {
            limit: Some(1),
            ..Default::default()
        })) {
            Response::Jobs(rows) => rows,
            other => panic!("{other:?}"),
        };
        assert_eq!(limited.len(), 1);
    }

    #[test]
    fn batch_submit_creates_count_jobs_in_one_request() {
        let d = daemon();
        let resp = d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, 3)
                .with_run_secs(60.0)
                .with_count(10_000),
        ));
        match resp {
            Response::SubmitAck(ack) => {
                assert_eq!(ack.count, 10_000);
                assert_eq!(ack.last - ack.first + 1, 10_000);
            }
            other => panic!("{other:?}"),
        }
        // An oversized batch is rejected with a typed error.
        match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::Individual, 100, 3).with_count(100_000),
        )) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::BadArg),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn scancel_pending_job() {
        let d = daemon();
        let resp = d.handle_line("SUBMIT normal array 64 1 600");
        let id: u64 = resp
            .split("jobs=")
            .nth(1)
            .unwrap()
            .split('-')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        let out = d.handle_line(&format!("SCANCEL {id}"));
        assert!(out.starts_with("OK cancelled"), "{out}");
        // Cancelling again fails gracefully with a typed NotFound.
        match d.handle(Request::Scancel(id)) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
        let out2 = d.handle_line(&format!("SCANCEL {id}"));
        assert!(out2.starts_with("ERR"), "{out2}");
    }

    #[test]
    fn sjob_reports_detail_and_latency() {
        let d = daemon();
        let ack = match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1).with_run_secs(60.0),
        )) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        let wait = match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 10.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        assert_eq!(wait.dispatched, 1);
        match d.handle(Request::Sjob(ack.first)) {
            Response::Job(detail) => {
                assert_eq!(detail.id, ack.first);
                assert_eq!(detail.latency_ns, Some(wait.latency_ns));
                assert!(detail.dispatched_secs.is_some());
            }
            other => panic!("{other:?}"),
        }
        match d.handle(Request::Sjob(999_999)) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wait_latency_matches_metrics_histogram() {
        let d = daemon();
        let ack = match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::TripleMode, 608, 1).with_run_secs(60.0),
        )) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        let wait = match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 10.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        // WAIT paces the daemon itself, so the histogram harvest happened.
        let h = d.metrics.sched_latency();
        assert_eq!(h.count(), 1);
        assert_eq!(h.max(), wait.latency_ns, "WAIT must report the histogram's value");
    }

    #[test]
    fn wait_on_unknown_job_is_not_found() {
        let d = daemon();
        match d.handle(Request::Wait {
            jobs: vec![12345],
            timeout_secs: 1.0,
        }) {
            Response::Error(e) => assert_eq!(e.code, super::super::api::ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wait_on_cancelled_job_returns_without_timeout() {
        let d = daemon();
        // A job too large for the user limit would pend forever; cancel it
        // and WAIT must return promptly with dispatched=0.
        let ack = match d.handle(Request::Submit(
            SubmitSpec::new(QosClass::Normal, JobType::Array, 64, 1).with_run_secs(600.0),
        )) {
            Response::SubmitAck(a) => a,
            other => panic!("{other:?}"),
        };
        assert!(matches!(
            d.handle(Request::Scancel(ack.first)),
            Response::Cancelled(_)
        ));
        let wait = match d.handle(Request::Wait {
            jobs: vec![ack.first],
            timeout_secs: 5.0,
        }) {
            Response::Wait(w) => w,
            other => panic!("{other:?}"),
        };
        assert!(!wait.timed_out);
        assert_eq!(wait.dispatched, 0);
    }

    #[test]
    fn bad_request_counts_as_error() {
        let d = daemon();
        let out = d.handle_line("SUBMIT nope nope nope nope");
        assert!(out.starts_with("ERR"));
        assert_eq!(d.metrics.requests_err.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn per_command_counters_accumulate() {
        let d = daemon();
        d.handle_line("PING");
        d.handle_line("PING");
        d.handle_line("SQUEUE");
        match d.handle(Request::Stats) {
            Response::Stats(s) => {
                assert_eq!(s.commands.get("ping").copied(), Some(2));
                assert_eq!(s.commands.get("squeue").copied(), Some(1));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hello_negotiates_v2_rendering() {
        let d = daemon();
        let (resp, negotiated) = d.handle_line_versioned("HELLO v2", ProtocolVersion::V1);
        assert_eq!(resp, "OK kind=hello proto=v2");
        assert_eq!(negotiated, Some(ProtocolVersion::V2));
        let (resp, _) = d.handle_line_versioned("PING", ProtocolVersion::V2);
        assert_eq!(resp, "OK kind=pong");
    }

    #[test]
    fn util_reports_cluster() {
        let d = daemon();
        let out = d.handle_line("UTIL");
        assert!(out.contains("total_cores=608"), "{out}");
        assert!(out.contains("utilization=0.0000"), "{out}");
    }

    #[test]
    fn shutdown_flips_flag() {
        let d = daemon();
        assert!(d.is_running());
        assert!(d.handle_line("SHUTDOWN").starts_with("OK"));
        assert!(!d.is_running());
    }
}
