//! A fixed-size worker thread pool (tokio substitute for connection
//! handling).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

/// A pool of worker threads consuming tasks from a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Task>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` workers (≥1).
    pub fn new(size: usize) -> Self {
        assert!(size > 0, "pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("spotcloud-worker-{i}"))
                    .spawn(move || loop {
                        let task = {
                            let guard = rx.lock().expect("pool queue poisoned");
                            guard.recv()
                        };
                        match task {
                            Ok(task) => task(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawning worker")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    /// Submit a task. Panics if the pool is shut down.
    pub fn execute(&self, task: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(task))
            .expect("workers gone");
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel, then join the workers.
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn tasks_run_concurrently() {
        let pool = ThreadPool::new(4);
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let b = Arc::clone(&barrier);
            let d = Arc::clone(&done);
            pool.execute(move || {
                // Deadlocks unless all 4 run in parallel.
                b.wait();
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }

    #[test]
    #[should_panic(expected = "at least one worker")]
    fn zero_workers_panics() {
        let _ = ThreadPool::new(0);
    }
}
