//! The daemon's line-based text protocol.
//!
//! Requests are single lines; responses are one or more lines terminated by
//! a blank line. Grammar:
//!
//! ```text
//! SUBMIT <normal|spot> <individual|array|triple> <tasks> <user> [run_secs]
//! SQUEUE
//! SCANCEL <job_id>
//! STATS
//! UTIL
//! PING
//! SHUTDOWN
//! ```

use crate::job::{JobType, QosClass};

/// A parsed request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Submit a job burst.
    Submit {
        /// QoS class.
        qos: QosClass,
        /// Launch type.
        job_type: JobType,
        /// Total tasks.
        tasks: u32,
        /// User id.
        user: u32,
        /// Run time in (virtual) seconds.
        run_secs: f64,
    },
    /// List pending + running jobs.
    Squeue,
    /// Cancel a job.
    Scancel(u64),
    /// Daemon + scheduler counters.
    Stats,
    /// Cluster utilization snapshot.
    Util,
    /// Liveness check.
    Ping,
    /// Stop the daemon.
    Shutdown,
}

/// Protocol-level errors.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum ApiError {
    #[error("empty request")]
    Empty,
    #[error("unknown command {0:?}")]
    UnknownCommand(String),
    #[error("{cmd}: expected {expected}")]
    BadArity {
        /// Command name.
        cmd: &'static str,
        /// Human-readable expectation.
        expected: &'static str,
    },
    #[error("invalid {what}: {value:?}")]
    BadValue {
        /// What failed to parse.
        what: &'static str,
        /// Offending token.
        value: String,
    },
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, ApiError> {
    let mut it = line.split_whitespace();
    let cmd = it.next().ok_or(ApiError::Empty)?;
    let rest: Vec<&str> = it.collect();
    match cmd.to_ascii_uppercase().as_str() {
        "SUBMIT" => {
            if rest.len() < 4 || rest.len() > 5 {
                return Err(ApiError::BadArity {
                    cmd: "SUBMIT",
                    expected: "<qos> <type> <tasks> <user> [run_secs]",
                });
            }
            let qos = match rest[0].to_ascii_lowercase().as_str() {
                "normal" => QosClass::Normal,
                "spot" => QosClass::Spot,
                other => {
                    return Err(ApiError::BadValue {
                        what: "qos",
                        value: other.to_string(),
                    })
                }
            };
            let job_type = match rest[1].to_ascii_lowercase().as_str() {
                "individual" => JobType::Individual,
                "array" => JobType::Array,
                "triple" => JobType::TripleMode,
                other => {
                    return Err(ApiError::BadValue {
                        what: "job type",
                        value: other.to_string(),
                    })
                }
            };
            let tasks: u32 = rest[2].parse().map_err(|_| ApiError::BadValue {
                what: "tasks",
                value: rest[2].to_string(),
            })?;
            if tasks == 0 {
                return Err(ApiError::BadValue {
                    what: "tasks",
                    value: "0".into(),
                });
            }
            let user: u32 = rest[3].parse().map_err(|_| ApiError::BadValue {
                what: "user",
                value: rest[3].to_string(),
            })?;
            let run_secs: f64 = match rest.get(4) {
                Some(s) => s.parse().map_err(|_| ApiError::BadValue {
                    what: "run_secs",
                    value: s.to_string(),
                })?,
                None => 3600.0,
            };
            Ok(Request::Submit {
                qos,
                job_type,
                tasks,
                user,
                run_secs,
            })
        }
        "SQUEUE" => Ok(Request::Squeue),
        "SCANCEL" => {
            let id: u64 = rest
                .first()
                .ok_or(ApiError::BadArity {
                    cmd: "SCANCEL",
                    expected: "<job_id>",
                })?
                .parse()
                .map_err(|_| ApiError::BadValue {
                    what: "job id",
                    value: rest.first().unwrap_or(&"").to_string(),
                })?;
            Ok(Request::Scancel(id))
        }
        "STATS" => Ok(Request::Stats),
        "UTIL" => Ok(Request::Util),
        "PING" => Ok(Request::Ping),
        "SHUTDOWN" => Ok(Request::Shutdown),
        other => Err(ApiError::UnknownCommand(other.to_string())),
    }
}

/// Render a successful response body (without the terminating blank line).
pub fn ok(body: impl AsRef<str>) -> String {
    let body = body.as_ref();
    if body.is_empty() {
        "OK".to_string()
    } else {
        format!("OK {body}")
    }
}

/// Render an error response.
pub fn err(e: &ApiError) -> String {
    format!("ERR {e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_submit() {
        let r = parse_request("SUBMIT normal triple 4096 1 600").unwrap();
        assert_eq!(
            r,
            Request::Submit {
                qos: QosClass::Normal,
                job_type: JobType::TripleMode,
                tasks: 4096,
                user: 1,
                run_secs: 600.0,
            }
        );
    }

    #[test]
    fn parse_submit_default_runtime() {
        match parse_request("submit spot array 128 9").unwrap() {
            Request::Submit { run_secs, qos, .. } => {
                assert_eq!(run_secs, 3600.0);
                assert_eq!(qos, QosClass::Spot);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_simple_commands() {
        assert_eq!(parse_request("SQUEUE").unwrap(), Request::Squeue);
        assert_eq!(parse_request("ping").unwrap(), Request::Ping);
        assert_eq!(parse_request("SCANCEL 42").unwrap(), Request::Scancel(42));
        assert_eq!(parse_request("STATS").unwrap(), Request::Stats);
        assert_eq!(parse_request("UTIL").unwrap(), Request::Util);
        assert_eq!(parse_request("SHUTDOWN").unwrap(), Request::Shutdown);
    }

    #[test]
    fn errors() {
        assert_eq!(parse_request("").unwrap_err(), ApiError::Empty);
        assert!(matches!(
            parse_request("FROBNICATE").unwrap_err(),
            ApiError::UnknownCommand(_)
        ));
        assert!(matches!(
            parse_request("SUBMIT normal").unwrap_err(),
            ApiError::BadArity { cmd: "SUBMIT", .. }
        ));
        assert!(matches!(
            parse_request("SUBMIT normal warp 1 1").unwrap_err(),
            ApiError::BadValue { what: "job type", .. }
        ));
        assert!(matches!(
            parse_request("SUBMIT normal array 0 1").unwrap_err(),
            ApiError::BadValue { what: "tasks", .. }
        ));
        assert!(matches!(
            parse_request("SCANCEL x").unwrap_err(),
            ApiError::BadValue { what: "job id", .. }
        ));
    }

    #[test]
    fn response_rendering() {
        assert_eq!(ok(""), "OK");
        assert_eq!(ok("job=3"), "OK job=3");
        assert!(err(&ApiError::Empty).starts_with("ERR "));
    }
}
