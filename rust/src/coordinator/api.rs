//! The typed protocol core: versioned requests, responses, payloads, and
//! error codes.
//!
//! This module defines *what* can be said between a client and the daemon;
//! [`super::codec`] owns *how* it is said on the wire (the v1 line grammar
//! kept byte-compatible with the original daemon, and the v2 tagged
//! `key=value` grammar negotiated via `HELLO`). The daemon core works purely
//! in these types — [`super::daemon::Daemon::handle`] is
//! `fn(&self, Request) -> Response` — and the typed [`super::client::Client`]
//! returns the payload structs below instead of raw strings.
//!
//! See `PROTOCOL.md` at the repository root for the full wire grammar.

use super::manifest::{Manifest, ManifestAck};
use crate::job::{JobState, JobType, QosClass};
use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

/// Wire protocol versions a connection can speak.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProtocolVersion {
    /// The original line grammar (`SUBMIT normal triple 4096 1 600`,
    /// free-form `OK ...` responses). Every connection starts here.
    #[default]
    V1,
    /// Tagged `key=value` records with typed, self-describing responses.
    V2,
    /// V2 plus the streaming `MSUBMIT` body: a manifest may arrive as
    /// `entries=<n> part=<i>/<k>` continuation records, lifting the
    /// single-line entry cap. Responses render exactly as v2.
    V21,
    /// Binary framing. After the (text) `HELLO v3` acknowledgement the
    /// connection switches to length-prefixed `[u32 LE len][opcode][body]`
    /// frames; text-opcode bodies carry the v2.1 line grammar verbatim, and
    /// `MSUBMIT` gains a varint-packed binary opcode parsed without
    /// per-entry text tokenization. Strict opt-in — v1/v2/v2.1 bytes are
    /// untouched. See PROTOCOL.md §v3.
    V3,
}

impl ProtocolVersion {
    /// Wire token ("v1" / "v2" / "v2.1" / "v3").
    pub fn as_str(self) -> &'static str {
        match self {
            ProtocolVersion::V1 => "v1",
            ProtocolVersion::V2 => "v2",
            ProtocolVersion::V21 => "v2.1",
            ProtocolVersion::V3 => "v3",
        }
    }

    /// Parse a wire token.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "v1" | "1" => Some(ProtocolVersion::V1),
            "v2" | "2" => Some(ProtocolVersion::V2),
            "v2.1" | "2.1" => Some(ProtocolVersion::V21),
            "v3" | "3" => Some(ProtocolVersion::V3),
            _ => None,
        }
    }

    /// Does this version speak the v2 record grammar? (v2.1 renders and
    /// parses exactly as v2; it only adds the chunked `MSUBMIT` body. v3's
    /// text-opcode bodies and rendered responses are also exactly v2.)
    pub fn is_v2(self) -> bool {
        matches!(
            self,
            ProtocolVersion::V2 | ProtocolVersion::V21 | ProtocolVersion::V3
        )
    }

    /// May `MSUBMIT` arrive chunked on this connection?
    pub fn chunked_msubmit(self) -> bool {
        matches!(self, ProtocolVersion::V21 | ProtocolVersion::V3)
    }

    /// Does this connection exchange length-prefixed binary frames after
    /// negotiation (v3) instead of newline-terminated text?
    pub fn binary_frames(self) -> bool {
        matches!(self, ProtocolVersion::V3)
    }
}

impl fmt::Display for ProtocolVersion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Machine-readable error classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Empty request line.
    Empty,
    /// Unrecognized command verb.
    UnknownCommand,
    /// Wrong number / shape of arguments.
    BadArity,
    /// An argument failed validation.
    BadArg,
    /// The referenced entity does not exist (e.g. cancel of an unknown job).
    NotFound,
    /// The operation is not supported in this protocol version or build.
    Unsupported,
    /// The daemon failed internally.
    Internal,
    /// The daemon refused the request to protect itself (rate limit,
    /// inflight budget, shedding, or an expired deadline budget). The
    /// request was **not** admitted; retry after backing off — v2 errors
    /// carry a `retry_after_ms` hint.
    Overloaded,
    /// The daemon is in the read-only degraded state (poisoned journal):
    /// mutations are refused because they could not be made durable, but
    /// reads (`SQUEUE`/`SJOB`/`WAIT`/`STATS`) still serve.
    ReadOnly,
}

impl ErrorCode {
    /// Wire token.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::Empty => "empty",
            ErrorCode::UnknownCommand => "unknown_command",
            ErrorCode::BadArity => "bad_arity",
            ErrorCode::BadArg => "bad_arg",
            ErrorCode::NotFound => "not_found",
            ErrorCode::Unsupported => "unsupported",
            ErrorCode::Internal => "internal",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::ReadOnly => "read_only",
        }
    }

    /// Parse a wire token.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "empty" => Some(ErrorCode::Empty),
            "unknown_command" => Some(ErrorCode::UnknownCommand),
            "bad_arity" => Some(ErrorCode::BadArity),
            "bad_arg" => Some(ErrorCode::BadArg),
            "not_found" => Some(ErrorCode::NotFound),
            "unsupported" => Some(ErrorCode::Unsupported),
            "internal" => Some(ErrorCode::Internal),
            "overloaded" => Some(ErrorCode::Overloaded),
            "read_only" => Some(ErrorCode::ReadOnly),
            _ => None,
        }
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A protocol-level error: a typed code plus a human-readable message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ApiError {
    /// Machine-readable class.
    pub code: ErrorCode,
    /// Single-line human-readable detail.
    pub message: String,
    /// Backoff hint for [`ErrorCode::Overloaded`]: how long the client
    /// should wait before retrying. Additive v2 wire key
    /// (`retry_after_ms=`); v1 peers never see it and parse `None`.
    pub retry_after_ms: Option<u64>,
}

impl ApiError {
    /// Build from a code and message.
    pub fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        ApiError {
            code,
            message: message.into(),
            retry_after_ms: None,
        }
    }

    /// Empty request line.
    pub fn empty() -> Self {
        Self::new(ErrorCode::Empty, "empty request")
    }

    /// Unknown command verb.
    pub fn unknown_command(cmd: &str) -> Self {
        Self::new(ErrorCode::UnknownCommand, format!("unknown command {cmd:?}"))
    }

    /// Wrong argument shape for a command.
    pub fn bad_arity(cmd: &str, expected: &str) -> Self {
        Self::new(ErrorCode::BadArity, format!("{cmd}: expected {expected}"))
    }

    /// Invalid argument value.
    pub fn bad_arg(what: &str, value: &str) -> Self {
        Self::new(ErrorCode::BadArg, format!("invalid {what}: {value:?}"))
    }

    /// Missing entity.
    pub fn not_found(what: impl Into<String>) -> Self {
        Self::new(ErrorCode::NotFound, what)
    }

    /// Unsupported operation.
    pub fn unsupported(what: impl Into<String>) -> Self {
        Self::new(ErrorCode::Unsupported, what)
    }

    /// Admission refused under overload, with a backoff hint.
    pub fn overloaded(what: impl Into<String>, retry_after_ms: u64) -> Self {
        ApiError {
            code: ErrorCode::Overloaded,
            message: what.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    /// Mutation refused because the daemon is read-only (poisoned journal).
    pub fn read_only(what: impl Into<String>) -> Self {
        Self::new(ErrorCode::ReadOnly, what)
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.message)
    }
}

impl std::error::Error for ApiError {}

/// Daemon health, as the overload control plane reports it. Ordered by
/// severity: `Healthy < Shedding < ReadOnly`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum HealthState {
    /// All admission gates open.
    #[default]
    Healthy,
    /// The daemon is refusing some cheap-to-refuse work (new
    /// `SUBMIT`/`MSUBMIT`) to protect interactive latency; reads and
    /// `WAIT` always serve.
    Shedding,
    /// The write-ahead journal is poisoned: every mutation is refused
    /// (typed `read_only`), reads still serve. Sticky until restart.
    ReadOnly,
}

impl HealthState {
    /// Wire token.
    pub fn as_str(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Shedding => "shedding",
            HealthState::ReadOnly => "read_only",
        }
    }

    /// Parse a wire token.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "healthy" => Some(HealthState::Healthy),
            "shedding" => Some(HealthState::Shedding),
            "read_only" => Some(HealthState::ReadOnly),
            _ => None,
        }
    }
}

impl fmt::Display for HealthState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The `HEALTH` verb's payload: current state plus the shed counters that
/// explain it. Also carried by `STATS` as an additive **v2 wire
/// extension** (`health_*` / `shed_*` keys); v1 responses omit it.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct HealthReport {
    /// Current state.
    pub state: HealthState,
    /// Seconds (wall) since the state last changed.
    pub since_secs: f64,
    /// Requests currently admitted and executing.
    pub inflight: u64,
    /// Global inflight-admission budget (0 = unlimited).
    pub inflight_budget: u64,
    /// `SUBMIT`s refused by the control plane.
    pub shed_submits: u64,
    /// `MSUBMIT`s (including chunked bodies) refused by the control plane.
    pub shed_msubmits: u64,
    /// Requests refused by a per-connection or per-user token bucket.
    pub rate_limited: u64,
    /// Requests dropped because their `deadline_ms=` budget expired
    /// before execution.
    pub deadline_expired: u64,
    /// Slow-consumer connections evicted by the reactor.
    pub conns_evicted: u64,
    /// Journal poison transitions (nonzero forces `ReadOnly`).
    pub journal_poisoned: u64,
}

/// A submission: one spec, optionally repeated `count` times so a whole
/// burst (e.g. 10,000 individual jobs) lands in a single RPC.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitSpec {
    /// QoS class.
    pub qos: QosClass,
    /// Launch type.
    pub job_type: JobType,
    /// Tasks per submission.
    pub tasks: u32,
    /// Submitting user id.
    pub user: u32,
    /// Per-job run time in virtual seconds.
    pub run_secs: f64,
    /// How many copies of the spec to submit atomically (batch submit).
    pub count: u32,
}

impl SubmitSpec {
    /// A single submission with the default one-hour run time.
    pub fn new(qos: QosClass, job_type: JobType, tasks: u32, user: u32) -> Self {
        SubmitSpec {
            qos,
            job_type,
            tasks,
            user,
            run_secs: 3600.0,
            count: 1,
        }
    }

    /// Builder: per-job run time (virtual seconds).
    pub fn with_run_secs(mut self, run_secs: f64) -> Self {
        self.run_secs = run_secs;
        self
    }

    /// Builder: batch count.
    pub fn with_count(mut self, count: u32) -> Self {
        self.count = count;
        self
    }
}

/// Server-side `SQUEUE` filters. All fields are conjunctive; `None` matches
/// everything.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SqueueFilter {
    /// Only this user's jobs.
    pub user: Option<u32>,
    /// Only this QoS class.
    pub qos: Option<QosClass>,
    /// Only this state (default: pending + running + requeued).
    pub state: Option<JobState>,
    /// Truncate the listing to this many rows.
    pub limit: Option<usize>,
}

impl SqueueFilter {
    /// True when no filter is set (the v1 default listing).
    pub fn is_empty(&self) -> bool {
        *self == SqueueFilter::default()
    }
}

/// A parsed request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Negotiate the protocol version for this connection.
    Hello(ProtocolVersion),
    /// Submit a burst of jobs (batch-first: `count` copies of the spec).
    Submit(SubmitSpec),
    /// Submit a heterogeneous manifest: per-entry specs, one RPC, one
    /// scheduler lock, partial-accept semantics (v2 only on the wire).
    MSubmit(Manifest),
    /// One part of a streaming (chunked) manifest body — v2.1 only. The
    /// transport assembles consecutive parts into one [`Manifest`] and
    /// admits it through the normal `MSUBMIT` path when the final part
    /// lands; intermediate parts are acknowledged with
    /// [`Response::ChunkAck`].
    MSubmitChunk(super::manifest::ManifestChunk),
    /// List jobs, optionally filtered.
    Squeue(SqueueFilter),
    /// Detail query for one job.
    Sjob(u64),
    /// Cancel a job.
    Scancel(u64),
    /// Block until the jobs' `DispatchDone` log records land (or timeout,
    /// in wall seconds) and report the virtual scheduling latency.
    Wait {
        /// Job ids to wait on.
        jobs: Vec<u64>,
        /// Wall-clock timeout in seconds.
        timeout_secs: f64,
    },
    /// Per-entry wait: block on "entry `entry` of manifest `manifest`"
    /// instead of an explicit id list — the daemon resolves the entry's id
    /// span through its manifest registry (v2 only on the wire; shares the
    /// `WAIT` verb).
    WaitEntry {
        /// Manifest id from the `MSUBMIT` ack.
        manifest: u64,
        /// Entry index within that manifest.
        entry: u32,
        /// Wall-clock timeout in seconds.
        timeout_secs: f64,
    },
    /// Re-attach to a prior manifest (by tag or id) and learn its
    /// per-entry settlement, so a client that lost its connection — or a
    /// daemon crash — collects exactly the not-yet-settled entries
    /// (v2 only on the wire).
    Resume(ResumeTarget),
    /// Daemon + scheduler counters.
    Stats,
    /// Cluster utilization snapshot.
    Util,
    /// Daemon health: overload state machine + shed counters. Served off
    /// atomics (never touches the scheduler lock) and allowed in every
    /// protocol version and every health state.
    Health,
    /// Liveness check.
    Ping,
    /// Stop the daemon.
    Shutdown,
}

/// What a `RESUME` re-attaches to.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ResumeTarget {
    /// The latest manifest registered under this tag.
    Tag(String),
    /// A specific manifest id (from the `MSUBMIT` ack or a prior resume).
    Manifest(u64),
}

/// Every command verb, in wire order (per-command metrics index off this).
pub const COMMANDS: [&str; 13] = [
    "HELLO", "SUBMIT", "MSUBMIT", "SQUEUE", "SJOB", "SCANCEL", "WAIT", "RESUME", "STATS", "UTIL",
    "HEALTH", "PING", "SHUTDOWN",
];

impl Request {
    /// The command verb (stable, uppercase; indexes [`COMMANDS`]).
    pub fn command_name(&self) -> &'static str {
        match self {
            Request::Hello(_) => "HELLO",
            Request::Submit(_) => "SUBMIT",
            Request::MSubmit(_) => "MSUBMIT",
            Request::MSubmitChunk(_) => "MSUBMIT",
            Request::Squeue(_) => "SQUEUE",
            Request::Sjob(_) => "SJOB",
            Request::Scancel(_) => "SCANCEL",
            Request::Wait { .. } => "WAIT",
            Request::WaitEntry { .. } => "WAIT",
            Request::Resume(_) => "RESUME",
            Request::Stats => "STATS",
            Request::Util => "UTIL",
            Request::Health => "HEALTH",
            Request::Ping => "PING",
            Request::Shutdown => "SHUTDOWN",
        }
    }
}

/// Acknowledgement of a (possibly batched) submission: the contiguous id
/// range the scheduler assigned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubmitAck {
    /// First assigned job id.
    pub first: u64,
    /// Last assigned job id.
    pub last: u64,
    /// Number of jobs created.
    pub count: u64,
}

impl SubmitAck {
    /// The assigned ids (the scheduler assigns them contiguously per RPC).
    pub fn ids(&self) -> impl Iterator<Item = u64> {
        let empty = self.count == 0;
        let (first, last) = (self.first, self.last);
        (first..=last).filter(move |_| !empty)
    }
}

impl fmt::Display for SubmitAck {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "jobs={}-{} count={}", self.first, self.last, self.count)
    }
}

/// One `SQUEUE` row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobSummary {
    /// Job id.
    pub id: u64,
    /// Launch type.
    pub job_type: JobType,
    /// Task count.
    pub tasks: u32,
    /// Owning user.
    pub user: u32,
    /// QoS class.
    pub qos: QosClass,
    /// Lifecycle state.
    pub state: JobState,
    /// Job tag (v2 wire extension: the v1 table is byte-compatible with
    /// the seed and cannot carry it, so a v1 listing parses as `None`).
    pub tag: Option<Arc<str>>,
}

/// Full per-job detail (`SJOB`). Times are virtual seconds since daemon
/// start; optional fields are absent until the event happens.
#[derive(Debug, Clone, PartialEq)]
pub struct JobDetail {
    /// Job id.
    pub id: u64,
    /// Launch type.
    pub job_type: JobType,
    /// Task count.
    pub tasks: u32,
    /// Owning user.
    pub user: u32,
    /// QoS class.
    pub qos: QosClass,
    /// Lifecycle state.
    pub state: JobState,
    /// Submission time.
    pub submit_secs: f64,
    /// Last time the job (re-)entered the pending queue.
    pub queue_secs: f64,
    /// Last start time.
    pub start_secs: Option<f64>,
    /// Terminal time.
    pub end_secs: Option<f64>,
    /// Preempt+requeue count.
    pub requeues: u32,
    /// Scheduler-recognized time (event log).
    pub recognized_secs: Option<f64>,
    /// Last dispatch-complete time (event log).
    pub dispatched_secs: Option<f64>,
    /// Virtual scheduling latency in ns (recognized → dispatched), the
    /// paper's per-job metric.
    pub latency_ns: Option<u64>,
    /// Job tag (flows from the submission manifest through the job table;
    /// `None` only when the peer predates the field).
    pub tag: Option<Arc<str>>,
}

/// Result of a `WAIT`: how many of the requested jobs dispatched, and the
/// burst's virtual scheduling latency (first recognized → last dispatched),
/// i.e. the paper's Figure-2 measurement, observable remotely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaitResult {
    /// Jobs the client asked about.
    pub requested: u32,
    /// Jobs whose `DispatchDone` record exists.
    pub dispatched: u32,
    /// True when the wall-clock timeout expired first.
    pub timed_out: bool,
    /// Virtual scheduling latency of the dispatched set in nanoseconds
    /// (0 until at least one job dispatched).
    pub latency_ns: u64,
}

impl fmt::Display for WaitResult {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "dispatched {}/{} latency={:.3}s{}",
            self.dispatched,
            self.requested,
            self.latency_ns as f64 / 1e9,
            if self.timed_out { " (timed out)" } else { "" }
        )
    }
}

/// Read/write-path contention counters and the write-lock hold-time
/// histogram summary — the daemon's concurrency contract, observable by
/// remote clients. Carried by `STATS` as a **v2 wire extension**: v2
/// responses append these keys, v1 responses omit them (and v2 parsers
/// accept their absence), so old clients and servers interoperate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ContentionStats {
    /// Requests served from the published snapshot (no scheduler lock).
    pub read_path_ops: u64,
    /// Scheduler-mutex acquisitions (mutating requests + pacing).
    pub write_locks: u64,
    /// `WAIT`s that parked on the completion hub.
    pub waits_parked: u64,
    /// Parked `WAIT`s that resolved (equal to `waits_parked` when quiescent).
    pub waits_resumed: u64,
    /// Write-lock hold-time samples recorded.
    pub lock_hold_count: u64,
    /// p50 wall time the scheduler write mutex was held (ns).
    pub lock_hold_p50_ns: u64,
    /// p99 wall time the scheduler write mutex was held (ns).
    pub lock_hold_p99_ns: u64,
    /// Longest wall time the scheduler write mutex was held (ns).
    pub lock_hold_max_ns: u64,
}

/// Which half of the coordinator a [`ShardStats`] row describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardKind {
    /// A front-door epoll reactor shard (one per reactor thread).
    Reactor,
    /// A back-end scheduler shard (one per partition in sharded mode).
    Sched,
}

impl ShardKind {
    /// Wire token.
    pub fn as_str(self) -> &'static str {
        match self {
            ShardKind::Reactor => "reactor",
            ShardKind::Sched => "sched",
        }
    }

    /// Parse a wire token.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "reactor" => Some(ShardKind::Reactor),
            "sched" => Some(ShardKind::Sched),
            _ => None,
        }
    }
}

/// Per-shard counters carried by `STATS` as an additive **v2 wire
/// extension** (`shard kind=… index=…` continuation records): one row per
/// reactor shard and one per scheduler shard. v1 responses omit them and
/// v2 parsers accept their absence, so old clients and servers
/// interoperate. Field meaning depends on [`ShardStats::kind`]: reactor
/// rows count epoll wakeups/ready events/connections/parked `WAIT`s;
/// sched rows count mutex acquisitions (in `wakeups`), dispatches (in
/// `events`), queue depth, and the shard mutex hold p99.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStats {
    /// Reactor or scheduler shard.
    pub kind: ShardKind,
    /// Shard index within its kind.
    pub index: u32,
    /// Human label (`reactor` / the shard's partition name).
    pub label: String,
    /// Reactor: `epoll_wait` returns. Sched: shard-mutex acquisitions.
    pub wakeups: u64,
    /// Reactor: readiness events delivered. Sched: dispatches.
    pub events: u64,
    /// Reactor: connections currently open. Sched: 0.
    pub connections: u64,
    /// Reactor: `WAIT`s currently parked on this shard. Sched: 0.
    pub parked: u64,
    /// Reactor: 0. Sched: pending jobs (queue depth) at last publish.
    pub queue_depth: u64,
    /// Reactor: 0. Sched: p99 shard-mutex hold (ns).
    pub lock_hold_p99_ns: u64,
}

/// Write-ahead-journal counters carried by `STATS` as an additive **v2
/// wire extension** (`journal_*` keys): present only when the daemon runs
/// with durability enabled, absent on journal-off daemons and v1 peers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JournalStats {
    /// Journal records appended (admissions, manifests, cancels).
    pub appends: u64,
    /// Appends whose acks waited for a covering `fsync` (equals `appends`
    /// under `fsync=always`; with group commit many acks ride one fsync).
    pub synced_appends: u64,
    /// Group-commit leader fsyncs. `synced_appends / group_commits` is the
    /// realized batching factor.
    pub group_commits: u64,
    /// Journal/allocator-log poison transitions; nonzero means some
    /// admissions were applied but not durably acked.
    pub poisoned: u64,
}

/// Daemon + scheduler counters (`STATS`).
#[derive(Debug, Clone, PartialEq)]
pub struct StatsSnapshot {
    /// Current virtual time (seconds).
    pub virtual_now_secs: f64,
    /// Jobs dispatched.
    pub dispatches: u64,
    /// Preemption victims.
    pub preemptions: u64,
    /// Requeue transactions.
    pub requeues: u64,
    /// Cron agent passes.
    pub cron_passes: u64,
    /// Main scheduling passes.
    pub main_passes: u64,
    /// Backfill passes.
    pub backfill_passes: u64,
    /// Triggered passes.
    pub triggered_passes: u64,
    /// Priority batches scored.
    pub score_batches: u64,
    /// Jobs scored across batches.
    pub jobs_scored: u64,
    /// Priority scorer backend name.
    pub scorer: String,
    /// Requests served OK.
    pub requests_ok: u64,
    /// Requests that errored.
    pub requests_err: u64,
    /// Jobs submitted through the API.
    pub jobs_submitted: u64,
    /// Count of harvested interactive scheduling latencies.
    pub sched_latency_count: u64,
    /// p50 of the virtual scheduling latency histogram (ns).
    pub sched_latency_p50_ns: u64,
    /// Per-command request counts (lowercase verb → count).
    pub commands: BTreeMap<String, u64>,
    /// Lock-path contention counters (v2 wire extension; `None` when the
    /// peer spoke v1 or predates the extension).
    pub contention: Option<ContentionStats>,
    /// Per-shard counters (v2 wire extension; empty when the peer spoke
    /// v1 or predates sharding).
    pub shards: Vec<ShardStats>,
    /// Write-ahead-journal counters (v2 wire extension; `None` on
    /// journal-off daemons and when the peer spoke v1).
    pub journal: Option<JournalStats>,
    /// Overload-control-plane state + shed counters (v2 wire extension;
    /// `None` when the peer spoke v1 or predates the extension).
    pub health: Option<HealthReport>,
    /// User-cardinality gauges (v2 wire extension; `None` when the peer
    /// spoke v1 or predates the extension). Makes bucket-map growth
    /// observable: a leak shows up as `users_tracked`/`buckets_live`
    /// climbing while `users_active` stays flat.
    pub users: Option<UserScaleStats>,
}

/// Live per-user state sizes (`STATS` v2 extension).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UserScaleStats {
    /// Distinct (qos, user) fairshare entries with nonzero charged usage.
    pub users_active: u64,
    /// `users_active` plus live pending-queue (qos, user) buckets.
    pub users_tracked: u64,
    /// Entries in the admission-control per-user token-bucket map.
    pub buckets_live: u64,
}

/// One manifest entry's settlement as `RESUME` reports it.
#[derive(Debug, Clone, PartialEq)]
pub struct ResumeEntry {
    /// Entry index within the manifest.
    pub index: u32,
    /// First job id of the entry's span.
    pub first: u64,
    /// Jobs in the span.
    pub count: u64,
    /// How many of those jobs are settled (dispatched or terminal —
    /// including retired/pruned jobs, which can never dispatch again).
    pub settled: u64,
    /// The entry's tag, if any.
    pub tag: Option<Arc<str>>,
}

impl ResumeEntry {
    /// Does this entry still have unsettled jobs worth waiting on?
    pub fn pending(&self) -> bool {
        self.settled < self.count
    }

    /// The entry's job ids.
    pub fn ids(&self) -> impl Iterator<Item = u64> {
        self.first..self.first + self.count
    }
}

/// `RESUME` outcome: the manifest id plus per-entry settlement. A client
/// resumes by collecting (`WAIT`ing on) exactly the entries with
/// `settled < count`.
#[derive(Debug, Clone, PartialEq)]
pub struct ResumeInfo {
    /// The resolved manifest id.
    pub manifest: u64,
    /// Accepted entries, ascending index order.
    pub entries: Vec<ResumeEntry>,
}

impl ResumeInfo {
    /// Entries that still have unsettled jobs.
    pub fn pending_entries(&self) -> impl Iterator<Item = &ResumeEntry> {
        self.entries.iter().filter(|e| e.pending())
    }
}

impl fmt::Display for ResumeInfo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let pending = self.pending_entries().count();
        write!(
            f,
            "manifest={} entries={} pending={}",
            self.manifest,
            self.entries.len(),
            pending
        )
    }
}

/// One scheduler shard's occupancy as `UTIL` reports it (additive v2
/// extension, one `shard …` record per scheduler shard; empty on v1).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardUtil {
    /// Shard index.
    pub index: u32,
    /// The shard's partition name.
    pub label: String,
    /// Allocated-core fraction of the shard's node slice.
    pub utilization: f64,
    /// Idle cores in the slice.
    pub idle_cores: u32,
    /// Total cores in the slice.
    pub total_cores: u32,
    /// Pending jobs queued on the shard.
    pub pending: usize,
    /// Running jobs on the shard.
    pub running: usize,
}

/// Cluster utilization snapshot (`UTIL`).
#[derive(Debug, Clone, PartialEq)]
pub struct UtilSnapshot {
    /// Allocated-core fraction.
    pub utilization: f64,
    /// Idle cores.
    pub idle_cores: u32,
    /// Fully-idle nodes.
    pub idle_nodes: u32,
    /// Total cores.
    pub total_cores: u32,
    /// Pending jobs.
    pub pending: usize,
    /// Running jobs.
    pub running: usize,
    /// Per-scheduler-shard occupancy (v2 wire extension; empty when the
    /// peer spoke v1 or the daemon is unsharded… the single shard is the
    /// whole table above, so no row is emitted).
    pub shards: Vec<ShardUtil>,
}

impl fmt::Display for UtilSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "utilization={:.4} idle_cores={} idle_nodes={} total_cores={} pending={} running={}",
            self.utilization,
            self.idle_cores,
            self.idle_nodes,
            self.total_cores,
            self.pending,
            self.running
        )
    }
}

/// A typed response. Errors are a first-class variant so
/// `Daemon::handle(Request) -> Response` is total.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// `PING` reply.
    Pong,
    /// `HELLO` reply: the version this connection now speaks.
    Hello(ProtocolVersion),
    /// `SHUTDOWN` acknowledged.
    ShuttingDown,
    /// Submission acknowledged.
    SubmitAck(SubmitAck),
    /// Manifest submission outcome: per-entry acks and typed rejects.
    ManifestAck(ManifestAck),
    /// Intermediate ack for one part of a chunked v2.1 `MSUBMIT` body
    /// (the final part answers with [`Response::ManifestAck`]).
    ChunkAck {
        /// The part just received (1-based).
        part: u32,
        /// Total parts the client declared.
        parts: u32,
        /// Entries buffered so far across the received parts.
        received: u64,
    },
    /// `SQUEUE` listing.
    Jobs(Vec<JobSummary>),
    /// `SJOB` detail.
    Job(JobDetail),
    /// `SCANCEL` acknowledged.
    Cancelled(u64),
    /// `WAIT` outcome.
    Wait(WaitResult),
    /// `RESUME` outcome.
    Resume(ResumeInfo),
    /// `STATS` snapshot.
    Stats(StatsSnapshot),
    /// `UTIL` snapshot.
    Util(UtilSnapshot),
    /// `HEALTH` report.
    Health(HealthReport),
    /// Any failure.
    Error(ApiError),
}

// ---- token helpers shared by both codec versions ---------------------------

/// Parse a QoS argument ("normal" / "spot").
pub fn parse_qos(s: &str) -> Option<QosClass> {
    match s.to_ascii_lowercase().as_str() {
        "normal" => Some(QosClass::Normal),
        "spot" => Some(QosClass::Spot),
        _ => None,
    }
}

/// Parse a job-type argument ("individual" / "array" / "triple").
pub fn parse_job_type(s: &str) -> Option<JobType> {
    match s.to_ascii_lowercase().as_str() {
        "individual" => Some(JobType::Individual),
        "array" => Some(JobType::Array),
        "triple" | "triple-mode" => Some(JobType::TripleMode),
        _ => None,
    }
}

/// The submit-argument token for a job type (inverse of [`parse_job_type`]).
pub fn job_type_arg(t: JobType) -> &'static str {
    match t {
        JobType::Individual => "individual",
        JobType::Array => "array",
        JobType::TripleMode => "triple",
    }
}

/// Lowercase wire token for a job state.
pub fn state_token(s: JobState) -> &'static str {
    match s {
        JobState::Pending => "pending",
        JobState::Running => "running",
        JobState::Completed => "completed",
        JobState::Requeued => "requeued",
        JobState::Cancelled => "cancelled",
        JobState::Suspended => "suspended",
    }
}

/// Parse a job-state token (case-insensitive, so the v1 `{:?}` table
/// rendering round-trips too).
pub fn parse_state(s: &str) -> Option<JobState> {
    match s.to_ascii_lowercase().as_str() {
        "pending" => Some(JobState::Pending),
        "running" => Some(JobState::Running),
        "completed" => Some(JobState::Completed),
        "requeued" => Some(JobState::Requeued),
        "cancelled" => Some(JobState::Cancelled),
        "suspended" => Some(JobState::Suspended),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn version_and_code_tokens_roundtrip() {
        for v in [
            ProtocolVersion::V1,
            ProtocolVersion::V2,
            ProtocolVersion::V21,
            ProtocolVersion::V3,
        ] {
            assert_eq!(ProtocolVersion::parse(v.as_str()), Some(v));
        }
        assert_eq!(ProtocolVersion::parse("2.1"), Some(ProtocolVersion::V21));
        assert_eq!(ProtocolVersion::parse("3"), Some(ProtocolVersion::V3));
        assert!(!ProtocolVersion::V1.is_v2());
        assert!(ProtocolVersion::V2.is_v2());
        assert!(ProtocolVersion::V21.is_v2());
        assert!(ProtocolVersion::V21.chunked_msubmit());
        assert!(!ProtocolVersion::V2.chunked_msubmit());
        // v3 text-opcode bodies speak the full v2.1 grammar; only v3
        // exchanges binary frames.
        assert!(ProtocolVersion::V3.is_v2());
        assert!(ProtocolVersion::V3.chunked_msubmit());
        assert!(ProtocolVersion::V3.binary_frames());
        assert!(!ProtocolVersion::V21.binary_frames());
        assert!(!ProtocolVersion::V1.binary_frames());
        for k in [ShardKind::Reactor, ShardKind::Sched] {
            assert_eq!(ShardKind::parse(k.as_str()), Some(k));
        }
        for c in [
            ErrorCode::Empty,
            ErrorCode::UnknownCommand,
            ErrorCode::BadArity,
            ErrorCode::BadArg,
            ErrorCode::NotFound,
            ErrorCode::Unsupported,
            ErrorCode::Internal,
            ErrorCode::Overloaded,
            ErrorCode::ReadOnly,
        ] {
            assert_eq!(ErrorCode::parse(c.as_str()), Some(c));
        }
        for h in [
            HealthState::Healthy,
            HealthState::Shedding,
            HealthState::ReadOnly,
        ] {
            assert_eq!(HealthState::parse(h.as_str()), Some(h));
        }
        assert!(HealthState::Healthy < HealthState::Shedding);
        assert!(HealthState::Shedding < HealthState::ReadOnly);
        let e = ApiError::overloaded("busy", 250);
        assert_eq!(e.code, ErrorCode::Overloaded);
        assert_eq!(e.retry_after_ms, Some(250));
        assert_eq!(ApiError::read_only("wal down").retry_after_ms, None);
    }

    #[test]
    fn state_tokens_roundtrip() {
        for s in [
            JobState::Pending,
            JobState::Running,
            JobState::Completed,
            JobState::Requeued,
            JobState::Cancelled,
            JobState::Suspended,
        ] {
            assert_eq!(parse_state(state_token(s)), Some(s));
            // The v1 table renders `{:?}`; that must parse too.
            assert_eq!(parse_state(&format!("{s:?}")), Some(s));
        }
    }

    #[test]
    fn submit_spec_builder() {
        let s = SubmitSpec::new(QosClass::Normal, JobType::Individual, 1, 7)
            .with_run_secs(60.0)
            .with_count(10_000);
        assert_eq!(s.count, 10_000);
        assert_eq!(s.run_secs, 60.0);
        assert_eq!(Request::Submit(s).command_name(), "SUBMIT");
    }

    #[test]
    fn submit_ack_ids() {
        let ack = SubmitAck {
            first: 5,
            last: 8,
            count: 4,
        };
        assert_eq!(ack.ids().collect::<Vec<_>>(), vec![5, 6, 7, 8]);
        assert_eq!(ack.to_string(), "jobs=5-8 count=4");
    }

    #[test]
    fn command_names_match_table() {
        let reqs = [
            Request::Hello(ProtocolVersion::V2),
            Request::Submit(SubmitSpec::new(QosClass::Spot, JobType::Array, 4, 1)),
            Request::MSubmit(Manifest::default()),
            Request::Squeue(SqueueFilter::default()),
            Request::Sjob(1),
            Request::Scancel(1),
            Request::Wait {
                jobs: vec![1],
                timeout_secs: 1.0,
            },
            Request::Resume(ResumeTarget::Tag("burst".into())),
            Request::Stats,
            Request::Util,
            Request::Health,
            Request::Ping,
            Request::Shutdown,
        ];
        for (r, name) in reqs.iter().zip(COMMANDS) {
            assert_eq!(r.command_name(), name);
        }
        // The per-entry wait form shares the WAIT verb (and metrics slot).
        let we = Request::WaitEntry {
            manifest: 1,
            entry: 0,
            timeout_secs: 1.0,
        };
        assert_eq!(we.command_name(), "WAIT");
        assert_eq!(
            Request::Resume(ResumeTarget::Manifest(3)).command_name(),
            "RESUME"
        );
    }

    #[test]
    fn resume_info_pending_entries() {
        let info = ResumeInfo {
            manifest: 2,
            entries: vec![
                ResumeEntry {
                    index: 0,
                    first: 1,
                    count: 4,
                    settled: 4,
                    tag: Some(Arc::from("done")),
                },
                ResumeEntry {
                    index: 1,
                    first: 5,
                    count: 3,
                    settled: 1,
                    tag: None,
                },
            ],
        };
        assert!(!info.entries[0].pending());
        assert!(info.entries[1].pending());
        assert_eq!(
            info.pending_entries().map(|e| e.index).collect::<Vec<_>>(),
            vec![1]
        );
        assert_eq!(info.entries[1].ids().collect::<Vec<_>>(), vec![5, 6, 7]);
        assert_eq!(info.to_string(), "manifest=2 entries=2 pending=1");
    }
}
