//! The Linux connection reactor: one thread, `epoll`, zero per-connection
//! polling.
//!
//! The threadpool server (kept for non-Linux targets in [`super::server`])
//! spends a thread per live connection and a 10 ms accept poll; at the
//! paper's target of thousands of concurrent interactive users that is a
//! thread pool the size of the user base. This module replaces it on Linux
//! with a readiness reactor built directly on the `epoll`/`eventfd`
//! syscalls (declared `extern "C"` — std already links libc, so this adds
//! **zero dependencies**):
//!
//! * **Zero-poll accept** — the listener is registered edge-triggered; the
//!   reactor drains `accept(2)` to `EWOULDBLOCK` on each readiness edge
//!   instead of sleeping 10 ms between polls. Accept *errors* back off
//!   exponentially (1 ms → 1 s) and are counted in
//!   [`DaemonMetrics::accept_errors`](super::metrics::DaemonMetrics).
//! * **Per-connection state machines** — every socket is nonblocking;
//!   partial request lines accumulate in a per-connection read buffer and
//!   partial responses drain from a write buffer under `EPOLLOUT`
//!   interest, so a slow or bursty peer never blocks the thread. Requests
//!   on one connection are answered strictly in order (pipelining).
//! * **Worker-pool dispatch** — complete request lines are handed to the
//!   existing small [`ThreadPool`] via
//!   [`Daemon::handle_line_nonblocking`]; completions come back over a
//!   queue + eventfd, so the reactor thread never executes scheduler code
//!   on the I/O path.
//! * **Native parked `WAIT`s** — a [`LineOutcome::Parked`] wait leaves its
//!   connection registered but inert; the daemon's completion hub wakes
//!   the reactor through the same eventfd
//!   ([`Daemon::subscribe_completions`]), replacing the dedicated waiter
//!   thread that used to sweep the parked registry.
//! * **Timer wheel** — idle expiry and `WAIT` deadlines live in a
//!   [`TimerWheel`]; the reactor sleeps in `epoll_wait` until the nearest
//!   deadline. An *idle* connection therefore costs one wheel entry and no
//!   wakeups at all — the invariant the `connection_scaling` bench gates
//!   on via [`DaemonMetrics::reactor_wakeups`](super::metrics::DaemonMetrics).
//! * **v3 binary frames** — once a connection negotiates `HELLO v3` its
//!   byte stream switches from newline-delimited text to length-prefixed
//!   frames ([`codec::decode_frame_header`]). `MSUBMIT` frames are parsed
//!   *on the reactor thread, straight out of the read buffer* — no
//!   intermediate text line, no per-entry `String` — and the typed result
//!   is what crosses to the worker pool
//!   ([`Daemon::handle_msubmit_frame`]); responses come back as
//!   ready-to-send frame bytes. Framed text requests reuse the ordinary
//!   line path with the response wrapped in an `OP_TEXT_RESP` frame.
//! * **Reactor shards** — [`super::server::Server::bind_sharded`] opens N
//!   `SO_REUSEPORT` listeners on one address ([`reuseport_listeners`]); the
//!   kernel spreads accepts across them and each shard runs this reactor on
//!   its own thread with its own epoll, timer wheel, wake eventfd, and
//!   [`ReactorShardMetrics`] block. A connection's whole lifetime (state
//!   machine, parked `WAIT`s, idle timer, chunked `MSUBMIT` assembly) stays
//!   on the shard that accepted it; shards share only the worker pool and
//!   the daemon. Shard counters record *in addition to* the daemon-wide
//!   roll-ups, so aggregate gates keep meaning "across all shards".

use super::codec;
use super::daemon::{Daemon, LineOutcome, TokenBucket};
use super::manifest::{ChunkAssembler, Manifest};
use super::metrics::ReactorShardMetrics;
use super::threadpool::ThreadPool;
use super::timerwheel::TimerWheel;
use crate::coordinator::api::{ApiError, ProtocolVersion, Response};
use std::io::{self, Read, Write};
use std::net::{Ipv4Addr, SocketAddrV4, TcpListener, TcpStream};
use std::os::raw::{c_int, c_uint, c_void};
use std::os::unix::io::{AsRawFd, FromRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---- raw epoll / eventfd bindings ------------------------------------------

const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const EPOLLET: u32 = 1 << 31;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_MOD: c_int = 3;

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;

/// `struct epoll_event` (packed on x86-64, as in the kernel ABI).
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy, Default)]
struct EpollEvent {
    events: u32,
    data: u64,
}

// ---- raw socket bindings for SO_REUSEPORT listeners -------------------------

const AF_INET: c_int = 2;
const SOCK_STREAM: c_int = 1;
const SOCK_CLOEXEC: c_int = 0o2000000;
const SOL_SOCKET: c_int = 1;
const SO_REUSEADDR: c_int = 2;
const SO_REUSEPORT: c_int = 15;
const LISTEN_BACKLOG: c_int = 1024;

/// `struct sockaddr_in` (kernel ABI; port and address in network order).
#[repr(C)]
struct SockaddrIn {
    sin_family: u16,
    sin_port: u16,
    sin_addr: u32,
    sin_zero: [u8; 8],
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(
        epfd: c_int,
        events: *mut EpollEvent,
        maxevents: c_int,
        timeout_ms: c_int,
    ) -> c_int;
    fn eventfd(initval: c_uint, flags: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
    fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
    fn setsockopt(
        fd: c_int,
        level: c_int,
        optname: c_int,
        optval: *const c_void,
        optlen: c_uint,
    ) -> c_int;
    fn bind(fd: c_int, addr: *const SockaddrIn, addrlen: c_uint) -> c_int;
    fn listen(fd: c_int, backlog: c_int) -> c_int;
}

/// One nonblocking IPv4 listener with `SO_REUSEADDR` + `SO_REUSEPORT` set
/// *before* `bind(2)` (std's `TcpListener::bind` cannot, which is why this
/// goes through the raw syscalls). The fd is owned by the returned
/// `TcpListener` from the moment it exists, so every error path closes it.
fn reuseport_listener(ip: Ipv4Addr, port: u16) -> io::Result<TcpListener> {
    let fd = unsafe { socket(AF_INET, SOCK_STREAM | SOCK_CLOEXEC, 0) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    let listener = unsafe { TcpListener::from_raw_fd(fd) };
    let one: c_int = 1;
    for opt in [SO_REUSEADDR, SO_REUSEPORT] {
        let rc = unsafe {
            setsockopt(
                fd,
                SOL_SOCKET,
                opt,
                &one as *const c_int as *const c_void,
                std::mem::size_of::<c_int>() as c_uint,
            )
        };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
    }
    let sa = SockaddrIn {
        sin_family: AF_INET as u16,
        sin_port: port.to_be(),
        sin_addr: u32::from(ip).to_be(),
        sin_zero: [0; 8],
    };
    let rc = unsafe { bind(fd, &sa, std::mem::size_of::<SockaddrIn>() as c_uint) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    if unsafe { listen(fd, LISTEN_BACKLOG) } < 0 {
        return Err(io::Error::last_os_error());
    }
    listener.set_nonblocking(true)?;
    Ok(listener)
}

/// `n` listeners sharing one address via `SO_REUSEPORT` — the kernel hashes
/// incoming connections across them, giving each reactor shard its own
/// accept queue with no user-space balancing. Port 0 resolves on the first
/// listener; the rest bind the resolved port so all shards share it.
pub(super) fn reuseport_listeners(addr: SocketAddrV4, n: usize) -> io::Result<Vec<TcpListener>> {
    let mut out = Vec::with_capacity(n.max(1));
    let mut port = addr.port();
    for _ in 0..n.max(1) {
        let listener = reuseport_listener(*addr.ip(), port)?;
        if port == 0 {
            port = match listener.local_addr()? {
                std::net::SocketAddr::V4(sa) => sa.port(),
                std::net::SocketAddr::V6(sa) => sa.port(),
            };
        }
        out.push(listener);
    }
    Ok(out)
}

/// Owned epoll instance.
struct Epoll {
    fd: RawFd,
}

impl Epoll {
    fn new() -> io::Result<Self> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Wait for readiness; `None` sleeps until an event arrives.
    fn wait(&self, events: &mut [EpollEvent], timeout: Option<Duration>) -> io::Result<usize> {
        let ms: c_int = match timeout {
            None => -1,
            Some(d) if d.is_zero() => 0,
            // Round up so a timer never fires a hair early and re-sleeps 0ms.
            Some(d) => (d.as_millis() + 1).min(i32::MAX as u128) as c_int,
        };
        loop {
            let rc =
                unsafe { epoll_wait(self.fd, events.as_mut_ptr(), events.len() as c_int, ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let e = io::Error::last_os_error();
            if e.kind() != io::ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

/// An eventfd the worker pool (and the WaitHub waker) use to interrupt
/// `epoll_wait`.
struct WakeFd {
    fd: RawFd,
}

impl WakeFd {
    fn new() -> io::Result<Self> {
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self { fd })
    }

    fn wake(&self) {
        let v: u64 = 1;
        // A full counter still leaves the fd readable; failure is benign.
        unsafe { write(self.fd, &v as *const u64 as *const c_void, 8) };
    }

    fn drain(&self) {
        let mut v: u64 = 0;
        loop {
            let rc = unsafe { read(self.fd, &mut v as *mut u64 as *mut c_void, 8) };
            if rc != 8 {
                break;
            }
        }
    }
}

impl Drop for WakeFd {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

// ---- tokens and the connection slab ----------------------------------------

/// Token of the listening socket.
const TOKEN_LISTENER: u64 = u64::MAX;
/// Token of the completion eventfd.
const TOKEN_WAKER: u64 = u64::MAX - 1;

fn token(idx: usize, gen: u32) -> u64 {
    ((gen as u64) << 32) | idx as u64
}

fn token_idx(tok: u64) -> usize {
    (tok & 0xffff_ffff) as usize
}

fn token_gen(tok: u64) -> u32 {
    (tok >> 32) as u32
}

/// One slab slot: the generation invalidates stale epoll events, timer
/// entries, and completions after the slot is reused.
struct Slot {
    gen: u32,
    conn: Option<Conn>,
}

/// Index-stable connection storage with O(1) insert/remove.
#[derive(Default)]
struct Slab {
    slots: Vec<Slot>,
    free: Vec<usize>,
}

impl Slab {
    fn insert(&mut self, conn: Conn) -> u64 {
        if let Some(i) = self.free.pop() {
            debug_assert!(self.slots[i].conn.is_none());
            self.slots[i].conn = Some(conn);
            token(i, self.slots[i].gen)
        } else {
            self.slots.push(Slot { gen: 0, conn: Some(conn) });
            token(self.slots.len() - 1, 0)
        }
    }

    /// The connection for `tok`, unless the slot was freed or reused.
    fn get_mut(&mut self, tok: u64) -> Option<&mut Conn> {
        let i = token_idx(tok);
        self.slots
            .get_mut(i)
            .filter(|s| s.gen == token_gen(tok))
            .and_then(|s| s.conn.as_mut())
    }

    fn remove(&mut self, tok: u64) -> Option<Conn> {
        let i = token_idx(tok);
        let slot = self.slots.get_mut(i)?;
        if slot.gen != token_gen(tok) {
            return None;
        }
        let conn = slot.conn.take();
        if conn.is_some() {
            slot.gen = slot.gen.wrapping_add(1);
            self.free.push(i);
        }
        conn
    }

    /// Tokens of every live connection.
    fn tokens(&self) -> Vec<u64> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.conn.is_some())
            .map(|(i, s)| token(i, s.gen))
            .collect()
    }
}

// ---- per-connection state ---------------------------------------------------

/// Cap on buffered unparsed request bytes per connection (a line longer
/// than this — or a pipelined backlog this deep — closes the connection).
const MAX_BUFFERED_BYTES: usize = 4 * 1024 * 1024;

/// Cap on unflushed response bytes per connection. A peer that pipelines
/// requests but never reads its responses stops getting new requests
/// executed once this much output is queued (the threadpool server got
/// this backpressure for free from its blocking writes); dispatch resumes
/// when `EPOLLOUT` drains the backlog. At most one in-flight response can
/// overshoot the cap, so per-connection memory stays bounded.
const MAX_WRITE_BACKLOG: usize = 4 * 1024 * 1024;

/// How long a connection may stay pinned at [`MAX_WRITE_BACKLOG`] before
/// the reactor evicts it. Backpressure alone caps the *per-connection*
/// memory but lets a peer that never reads hold its buffered responses
/// forever; past this grace the connection is closed and counted
/// ([`ReactorShardMetrics::evictions`]), freeing the backlog.
const EVICT_GRACE: Duration = Duration::from_secs(5);

/// Shrink a drained per-connection buffer back down once its burst-sized
/// allocation would otherwise be retained for the connection's lifetime.
const BUF_SHRINK_THRESHOLD: usize = 64 * 1024;

/// One connection's state machine.
struct Conn {
    stream: TcpStream,
    /// Request bytes (partial lines survive readiness boundaries). The
    /// prefix up to `read_pos` is consumed; it is dropped lazily so a deep
    /// pipelined backlog does not pay a memmove per extracted line.
    read_buf: Vec<u8>,
    /// Consumed prefix of `read_buf`.
    read_pos: usize,
    /// Bytes of `read_buf` already scanned for a newline (≥ `read_pos`).
    scan_pos: usize,
    /// Rendered-but-unsent response bytes.
    write_buf: Vec<u8>,
    /// Bytes of `write_buf` already written.
    write_pos: usize,
    /// Negotiated protocol version (`HELLO` upgrades it).
    version: ProtocolVersion,
    /// Chunked-`MSUBMIT` assembly state (v2.1). Shared with the worker
    /// executing this connection's in-flight line; `busy` guarantees at
    /// most one such worker, so the mutex is for `Send`, not contention.
    chunks: Arc<Mutex<ChunkAssembler>>,
    /// A request line is in flight on the worker pool; further pipelined
    /// lines wait in `read_buf` so responses stay in order.
    busy: bool,
    /// A `WAIT` parked this connection.
    parked: Option<super::daemon::ParkedWait>,
    /// Peer is gone; the slot lingers only until in-flight work resolves.
    dead: bool,
    /// Peer half-closed (EOF on read). Already-buffered requests still
    /// execute and their responses still go out; the connection closes
    /// once everything in flight has drained.
    peer_eof: bool,
    /// `EPOLLOUT` interest is armed (write buffer could not fully drain).
    wants_write: bool,
    /// Close the connection if nothing happens before this instant.
    idle_deadline: Instant,
    /// An idle entry for this connection is in the wheel.
    idle_timer_armed: bool,
    /// When `accept(2)` returned this socket (accept-to-first-byte metric).
    accepted_at: Instant,
    /// First response byte has been written (metric recorded).
    first_byte_sent: bool,
    /// Per-connection request-line token bucket
    /// ([`super::daemon::OverloadConfig::conn_rate`]); `None` when the
    /// limit is disabled. Refusals are rendered directly on the reactor
    /// thread — an over-rate line never costs a worker turn.
    bucket: Option<TokenBucket>,
    /// A slow-consumer eviction deadline is in the wheel (armed when the
    /// write backlog pins at [`MAX_WRITE_BACKLOG`]; the timer re-checks).
    evict_armed: bool,
}

impl Conn {
    /// Unparsed bytes still buffered (what the back-pressure cap bounds).
    fn buffered_len(&self) -> usize {
        self.read_buf.len() - self.read_pos
    }

    /// Extract the next complete line, or `None` (partial bytes stay put).
    /// Consumption only advances `read_pos`; the prefix is compacted away
    /// once it dominates the buffer, so extracting N pipelined lines costs
    /// O(bytes) total, not O(N × backlog).
    fn take_line(&mut self) -> Option<String> {
        match self.read_buf[self.scan_pos..].iter().position(|&b| b == b'\n') {
            None => {
                self.scan_pos = self.read_buf.len();
                None
            }
            Some(off) => {
                let nl = self.scan_pos + off;
                let mut end = nl;
                while end > self.read_pos && self.read_buf[end - 1] == b'\r' {
                    end -= 1;
                }
                let line =
                    String::from_utf8_lossy(&self.read_buf[self.read_pos..end]).into_owned();
                self.read_pos = nl + 1;
                self.scan_pos = self.read_pos;
                if self.read_pos == self.read_buf.len() {
                    self.read_buf.clear();
                    if self.read_buf.capacity() > BUF_SHRINK_THRESHOLD {
                        self.read_buf.shrink_to(READ_CHUNK);
                    }
                    self.read_pos = 0;
                    self.scan_pos = 0;
                } else if self.read_pos >= 4096 && self.read_pos * 2 >= self.read_buf.len() {
                    self.read_buf.drain(..self.read_pos);
                    self.scan_pos -= self.read_pos;
                    self.read_pos = 0;
                }
                Some(line)
            }
        }
    }

    /// Locate the next complete v3 frame without consuming it:
    /// `Ok(Some((opcode, payload_start, frame_end)))` as offsets into
    /// `read_buf`, `Ok(None)` while bytes are still in flight. The payload
    /// stays in place so `MSUBMIT` bodies parse zero-copy out of the read
    /// buffer. A malformed length prefix is `Err` — the stream cannot be
    /// resynced and the connection must close after a typed error.
    fn peek_frame(&self) -> Result<Option<(u8, usize, usize)>, ApiError> {
        let avail = &self.read_buf[self.read_pos..];
        let len = match codec::decode_frame_header(avail)? {
            None => return Ok(None),
            Some(len) => len,
        };
        if avail.len() < codec::FRAME_HEADER_BYTES + len {
            return Ok(None);
        }
        let start = self.read_pos + codec::FRAME_HEADER_BYTES;
        Ok(Some((self.read_buf[start], start + 1, start + len)))
    }

    /// Consume a peeked frame (everything before `end`), compacting the
    /// buffer on the same policy as [`Conn::take_line`].
    fn consume_to(&mut self, end: usize) {
        self.read_pos = end;
        self.scan_pos = self.read_pos;
        if self.read_pos == self.read_buf.len() {
            self.read_buf.clear();
            if self.read_buf.capacity() > BUF_SHRINK_THRESHOLD {
                self.read_buf.shrink_to(READ_CHUNK);
            }
            self.read_pos = 0;
            self.scan_pos = 0;
        } else if self.read_pos >= 4096 && self.read_pos * 2 >= self.read_buf.len() {
            self.read_buf.drain(..self.read_pos);
            self.scan_pos -= self.read_pos;
            self.read_pos = 0;
        }
    }
}

/// What the extraction step found on a connection's read buffer — the
/// text and v3-frame dialects converge here so dispatch is shared.
enum NextReq {
    /// Nothing complete buffered (or backpressured): stop advancing.
    None,
    /// The per-connection rate limit refused the request (retry hint ms).
    Refused(u64),
    /// A text request line — from a bare line or an `OP_TEXT_REQ` frame.
    Line(String),
    /// An `OP_MSUBMIT` frame, already parsed on the reactor thread.
    Manifest(Result<Manifest, ApiError>),
    /// A frame with an opcode this server does not dispatch.
    BadOpcode(u8),
    /// The length prefix itself is invalid; the stream cannot resync.
    FrameError(ApiError),
}

/// Timer payloads: validated lazily against the slab on expiry.
enum TimerItem {
    /// Idle-deadline check for a connection token.
    Idle(u64),
    /// A parked `WAIT`'s wall deadline.
    WaitDeadline(u64),
    /// Retry `accept(2)` after an error backoff.
    AcceptRetry,
    /// Slow-consumer check: still pinned at the write-backlog cap when
    /// this fires → evict the connection.
    EvictDeadline(u64),
}

/// One finished request coming back from the worker pool.
enum Completion {
    /// A text-path outcome (response body or parked `WAIT`).
    Line(LineOutcome),
    /// Ready-to-send v3 frame bytes (binary `MSUBMIT` path).
    Frame(Vec<u8>),
}

/// Completed requests coming back from the worker pool.
struct Completions {
    queue: Mutex<Vec<(u64, Completion)>>,
    inflight: AtomicUsize,
    waker: WakeFd,
}

// ---- the reactor ------------------------------------------------------------

const MAX_EVENTS: usize = 256;
const READ_CHUNK: usize = 16 * 1024;
/// Wheel granularity / size: 50 ms buckets, 512 slots (25.6 s horizon;
/// longer deadlines are just re-examined once per revolution).
const WHEEL_GRANULARITY: Duration = Duration::from_millis(50);
const WHEEL_SLOTS: usize = 512;
/// While `WAIT`s are parked, virtual-time pacing passes are scheduled at
/// this cadence (the role the old waiter thread played) — on the *worker
/// pool*, never the reactor thread, with an in-flight guard
/// ([`Reactor::schedule_pace`]). With nothing parked the reactor sleeps
/// indefinitely.
const PACE_TICK: Duration = Duration::from_millis(20);
const ACCEPT_BACKOFF_START: Duration = Duration::from_millis(1);
const ACCEPT_BACKOFF_CEILING: Duration = Duration::from_secs(1);
/// Cap on concurrently parked `WAIT`s (same back-pressure rationale as the
/// threadpool server's registry).
const MAX_PARKED_WAITS: usize = 4096;

pub(super) struct Reactor<'a> {
    epoll: Epoll,
    listener: &'a TcpListener,
    daemon: Arc<Daemon>,
    pool: Arc<ThreadPool>,
    comps: Arc<Completions>,
    slab: Slab,
    wheel: TimerWheel<TimerItem>,
    parked_tokens: Vec<u64>,
    parked_gauge: Arc<AtomicUsize>,
    /// This shard's counter block (also rolled up in the daemon metrics).
    shard: Arc<ReactorShardMetrics>,
    idle_timeout: Duration,
    accept_backoff: Duration,
    accept_paused_until: Option<Instant>,
    /// A virtual-time pacing pass is running on the worker pool. Pacing for
    /// parked `WAIT`s used to run inline on the reactor thread — a loaded
    /// scheduler pass (a 100k-job dispatch burst catching up the clock)
    /// stalled accept/read/write for the whole pace. The guard keeps at
    /// most one pace in flight.
    pace_inflight: Arc<AtomicBool>,
    /// Earliest instant the next pace may be scheduled: paces run at the
    /// `PACE_TICK` cadence, not once per reactor wakeup (an unthrottled
    /// offload would busy-spin the reactor and a worker for the life of
    /// any parked `WAIT`).
    next_pace: Instant,
    shutting_down: bool,
}

/// Run the reactor until daemon shutdown. Setup failures are reported and
/// leave the server not serving (they indicate a broken host, not load).
pub(super) fn serve(
    listener: &TcpListener,
    daemon: &Arc<Daemon>,
    pool: &Arc<ThreadPool>,
    idle_timeout: Duration,
    parked_gauge: &Arc<AtomicUsize>,
    shard: &Arc<ReactorShardMetrics>,
) {
    match Reactor::new(listener, daemon, pool, idle_timeout, parked_gauge, shard) {
        Ok(mut r) => r.run(),
        Err(e) => eprintln!("reactor setup failed, server not serving: {e}"),
    }
}

impl<'a> Reactor<'a> {
    fn new(
        listener: &'a TcpListener,
        daemon: &Arc<Daemon>,
        pool: &Arc<ThreadPool>,
        idle_timeout: Duration,
        parked_gauge: &Arc<AtomicUsize>,
        shard: &Arc<ReactorShardMetrics>,
    ) -> io::Result<Self> {
        let epoll = Epoll::new()?;
        let comps = Arc::new(Completions {
            queue: Mutex::new(Vec::new()),
            inflight: AtomicUsize::new(0),
            waker: WakeFd::new()?,
        });
        epoll.ctl(
            EPOLL_CTL_ADD,
            listener.as_raw_fd(),
            EPOLLIN | EPOLLET,
            TOKEN_LISTENER,
        )?;
        epoll.ctl(EPOLL_CTL_ADD, comps.waker.fd, EPOLLIN | EPOLLET, TOKEN_WAKER)?;
        Ok(Self {
            epoll,
            listener,
            daemon: Arc::clone(daemon),
            pool: Arc::clone(pool),
            comps,
            slab: Slab::default(),
            wheel: TimerWheel::new(WHEEL_GRANULARITY, WHEEL_SLOTS),
            parked_tokens: Vec::new(),
            parked_gauge: Arc::clone(parked_gauge),
            shard: Arc::clone(shard),
            idle_timeout,
            accept_backoff: ACCEPT_BACKOFF_START,
            accept_paused_until: None,
            pace_inflight: Arc::new(AtomicBool::new(false)),
            next_pace: Instant::now(),
            shutting_down: false,
        })
    }

    fn run(&mut self) {
        self.daemon
            .metrics
            .reactor_threads_started
            .fetch_add(1, Ordering::Relaxed);
        // Completion-hub progress (dispatches, terminal transitions,
        // shutdown) wakes epoll_wait through the eventfd — the reactor
        // replaces the dedicated waiter thread.
        let hub_comps = Arc::clone(&self.comps);
        let sub = self
            .daemon
            .subscribe_completions(Box::new(move || hub_comps.waker.wake()));
        let mut events = [EpollEvent::default(); MAX_EVENTS];
        loop {
            self.drain_completions();
            if !self.daemon.is_running() {
                break;
            }
            if !self.parked_tokens.is_empty() {
                // Virtual time must advance for parked waits even when no
                // pacer thread runs (the blocked request used to pace from
                // its own worker) — but never on THIS thread: a loaded
                // scheduler pass would stall all I/O for the pace duration.
                self.schedule_pace();
                self.poll_parked();
            }
            self.fire_timers();
            if !self.daemon.is_running() {
                break;
            }
            let timeout = self.next_timeout();
            let n = match self.epoll.wait(&mut events, timeout) {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("epoll_wait failed: {e}");
                    break;
                }
            };
            self.daemon.metrics.record_reactor_wakeup(n as u64);
            self.shard.record_wakeup(n as u64);
            for ev in &events[..n] {
                let tok = ev.data;
                let flags = ev.events;
                match tok {
                    TOKEN_LISTENER => self.drain_accept(),
                    TOKEN_WAKER => self.comps.waker.drain(),
                    _ => self.on_conn_event(tok, flags),
                }
            }
        }
        self.daemon.unsubscribe_completions(sub);
        self.cleanup();
    }

    /// Offload one virtual-time pacing pass onto the worker pool, at most
    /// once per `PACE_TICK` and never with a previous pace still in flight
    /// (back-to-back paces are pointless and would pile the pool up behind
    /// the scheduler mutex — and an unthrottled reschedule would busy-spin
    /// reactor + worker for the life of a parked `WAIT`). No completion
    /// wake is needed: a pace that lands dispatch/terminal progress already
    /// wakes `epoll_wait` through the completion hub's eventfd
    /// subscription, and a progress-free pace has nothing to resolve — the
    /// `next_pace`-capped sleep brings the loop back for the next tick.
    fn schedule_pace(&mut self) {
        let now = Instant::now();
        if now < self.next_pace {
            return;
        }
        // Re-arm the tick before the in-flight check: if a long pace is
        // still running, the next attempt is a tick away — a stale
        // `next_pace` would otherwise zero the epoll timeout and spin.
        self.next_pace = now + PACE_TICK;
        if self
            .pace_inflight
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return;
        }
        self.daemon
            .metrics
            .pace_offloads
            .fetch_add(1, Ordering::Relaxed);
        let daemon = Arc::clone(&self.daemon);
        let flag = Arc::clone(&self.pace_inflight);
        self.pool.execute(move || {
            daemon.pace();
            flag.store(false, Ordering::Release);
        });
    }

    /// How long `epoll_wait` may sleep: until the nearest timer, capped at
    /// the next pace tick while waits are parked; forever when nothing
    /// pends.
    fn next_timeout(&self) -> Option<Duration> {
        let mut deadline = self.wheel.next_deadline();
        if !self.parked_tokens.is_empty() {
            let pace = self.next_pace.max(Instant::now());
            deadline = Some(deadline.map_or(pace, |d| d.min(pace)));
        }
        deadline.map(|d| d.saturating_duration_since(Instant::now()))
    }

    // ---- accept path -------------------------------------------------------

    fn drain_accept(&mut self) {
        if self
            .accept_paused_until
            .is_some_and(|until| Instant::now() < until)
        {
            return; // backing off; the AcceptRetry timer re-drains
        }
        self.accept_paused_until = None;
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    self.accept_backoff = ACCEPT_BACKOFF_START;
                    if let Err(e) = self.register_conn(stream) {
                        eprintln!("connection setup error: {e}");
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) => {
                    // Transient accept failures (EMFILE, ECONNABORTED, …):
                    // count, back off exponentially, retry on a timer
                    // instead of spinning or sleeping a flat interval.
                    self.daemon
                        .metrics
                        .accept_errors
                        .fetch_add(1, Ordering::Relaxed);
                    eprintln!("accept error: {e}");
                    let pause = self.accept_backoff;
                    self.accept_backoff = (self.accept_backoff * 2).min(ACCEPT_BACKOFF_CEILING);
                    let until = Instant::now() + pause;
                    self.accept_paused_until = Some(until);
                    self.wheel.insert(until, TimerItem::AcceptRetry);
                    break;
                }
            }
        }
    }

    fn register_conn(&mut self, stream: TcpStream) -> io::Result<()> {
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true).ok();
        let fd = stream.as_raw_fd();
        let now = Instant::now();
        let ov = self.daemon.overload_config();
        let bucket = if ov.conn_rate > 0.0 {
            Some(TokenBucket::new(ov.conn_rate, ov.conn_burst, now))
        } else {
            None
        };
        let conn = Conn {
            stream,
            read_buf: Vec::new(),
            read_pos: 0,
            scan_pos: 0,
            write_buf: Vec::new(),
            write_pos: 0,
            version: ProtocolVersion::V1,
            chunks: Arc::new(Mutex::new(ChunkAssembler::new())),
            busy: false,
            parked: None,
            dead: false,
            peer_eof: false,
            wants_write: false,
            idle_deadline: now + self.idle_timeout,
            idle_timer_armed: true,
            accepted_at: now,
            first_byte_sent: false,
            bucket,
            evict_armed: false,
        };
        let tok = self.slab.insert(conn);
        if let Err(e) = self.epoll.ctl(EPOLL_CTL_ADD, fd, EPOLLIN | EPOLLET, tok) {
            self.slab.remove(tok);
            return Err(e);
        }
        self.wheel.insert(now + self.idle_timeout, TimerItem::Idle(tok));
        self.daemon
            .metrics
            .connections_accepted
            .fetch_add(1, Ordering::Relaxed);
        self.shard.accepted.fetch_add(1, Ordering::Relaxed);
        self.shard.connections.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Free a slab slot and keep the shard's live-connection gauge honest
    /// (every removal funnels through here).
    fn remove_conn(&mut self, tok: u64) {
        if self.slab.remove(tok).is_some() {
            self.shard.connections.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Publish the parked-`WAIT` count to the server gauge and this
    /// shard's counter block.
    fn sync_parked_gauge(&self) {
        let n = self.parked_tokens.len();
        self.parked_gauge.store(n, Ordering::Relaxed);
        self.shard.parked_waits.store(n as u64, Ordering::Relaxed);
    }

    // ---- connection I/O ----------------------------------------------------

    fn on_conn_event(&mut self, tok: u64, flags: u32) {
        if self.slab.get_mut(tok).is_none() {
            return; // stale event for a freed slot
        }
        if flags & EPOLLOUT != 0 {
            self.try_flush(tok);
            self.maybe_close_eof(tok);
        }
        if flags & EPOLLIN != 0 {
            // Read first even under ERR/HUP: final bytes (a last pipelined
            // request) may still be pending, and read() surfaces the error
            // itself if there are none.
            self.on_readable(tok);
        } else if flags & (EPOLLERR | EPOLLHUP) != 0 {
            self.close_token(tok);
        }
    }

    fn on_readable(&mut self, tok: u64) {
        let mut buf = [0u8; READ_CHUNK];
        let mut got_bytes = false;
        let mut saw_eof = false;
        let mut closed = false;
        {
            let Some(conn) = self.slab.get_mut(tok) else { return };
            if conn.dead {
                return;
            }
            // A v3 connection must be able to buffer one maximum-size frame
            // on top of the pipelined backlog the text cap allows; a text
            // connection keeps the original line-length bound.
            let buffer_cap = if conn.version.binary_frames() {
                MAX_BUFFERED_BYTES + codec::MAX_FRAME_BYTES
            } else {
                MAX_BUFFERED_BYTES
            };
            // Edge-triggered: drain to EWOULDBLOCK so no edge is lost.
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        // Half-close: already-buffered requests still run to
                        // completion before the connection closes.
                        conn.peer_eof = true;
                        saw_eof = true;
                        break;
                    }
                    Ok(n) => {
                        conn.read_buf.extend_from_slice(&buf[..n]);
                        got_bytes = true;
                        if conn.buffered_len() > buffer_cap {
                            closed = true; // abusive line length / backlog
                            break;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        closed = true;
                        break;
                    }
                }
            }
        }
        if closed {
            self.close_token(tok);
            return;
        }
        if got_bytes {
            self.touch_idle(tok);
        }
        if got_bytes || saw_eof {
            self.maybe_close_eof(tok);
        }
    }

    /// Advance the connection, then close it if the peer hit EOF and
    /// nothing remains in flight or unflushed.
    fn maybe_close_eof(&mut self, tok: u64) {
        self.advance_conn(tok);
        let close = matches!(
            self.slab.get_mut(tok),
            Some(c) if c.peer_eof && !c.dead && !c.busy && c.parked.is_none()
                && c.write_pos >= c.write_buf.len()
        );
        if close {
            self.close_token(tok);
        }
    }

    /// Extract the next complete text request line, applying the blank
    /// keep-alive skip and the per-connection rate limit. Marks the
    /// connection busy when a line is handed out for dispatch.
    fn next_line(conn: &mut Conn) -> NextReq {
        loop {
            match conn.take_line() {
                None => return NextReq::None,
                Some(line) => {
                    if line.is_empty() {
                        continue; // blank keep-alive line
                    }
                    // Per-connection rate limit: an over-rate line is
                    // refused right here on the reactor thread — no
                    // worker turn, no scheduler lock, just a rendered
                    // `overloaded` with the bucket's retry hint.
                    let refused = match conn.bucket.as_mut() {
                        Some(bucket) => bucket.try_take(Instant::now()).err(),
                        None => None,
                    };
                    match refused {
                        Some(retry_ms) => return NextReq::Refused(retry_ms),
                        None => {
                            conn.busy = true;
                            return NextReq::Line(line);
                        }
                    }
                }
            }
        }
    }

    /// Extract the next complete v3 frame. `OP_MSUBMIT` payloads are parsed
    /// here, zero-copy out of the read buffer, so the worker pool receives
    /// a typed manifest instead of re-tokenizing text. The rate limit
    /// charges per frame, exactly as the text path charges per line.
    fn next_frame(conn: &mut Conn) -> NextReq {
        let (opcode, payload_start, end) = match conn.peek_frame() {
            Err(e) => return NextReq::FrameError(e),
            Ok(None) => return NextReq::None,
            Ok(Some(found)) => found,
        };
        let refused = match conn.bucket.as_mut() {
            Some(bucket) => bucket.try_take(Instant::now()).err(),
            None => None,
        };
        if let Some(retry_ms) = refused {
            conn.consume_to(end);
            return NextReq::Refused(retry_ms);
        }
        match opcode {
            codec::OP_TEXT_REQ => {
                let line =
                    String::from_utf8_lossy(&conn.read_buf[payload_start..end]).into_owned();
                conn.consume_to(end);
                conn.busy = true;
                NextReq::Line(line)
            }
            codec::OP_MSUBMIT => {
                let parsed = codec::parse_msubmit_v3(&conn.read_buf[payload_start..end]);
                conn.consume_to(end);
                conn.busy = true;
                NextReq::Manifest(parsed)
            }
            other => {
                conn.consume_to(end);
                NextReq::BadOpcode(other)
            }
        }
    }

    /// Dispatch the next complete request (if any) to the worker pool. At
    /// most one request per connection is in flight, which is what keeps
    /// pipelined responses in order — for framed and text dialects alike.
    fn advance_conn(&mut self, tok: u64) {
        if self.shutting_down {
            return;
        }
        loop {
            let next = {
                let Some(conn) = self.slab.get_mut(tok) else { return };
                if conn.busy || conn.parked.is_some() || conn.dead {
                    return;
                }
                // Response backpressure: don't execute further pipelined
                // requests for a peer that is not reading its responses.
                // The EPOLLOUT flush path re-enters advance_conn when the
                // backlog drains. A peer that *stays* pinned here is a
                // slow consumer: arm the eviction deadline — the timer
                // re-checks, and a backlog still at the cap closes the
                // connection and frees its buffered responses.
                if conn.write_buf.len() - conn.write_pos > MAX_WRITE_BACKLOG {
                    if !conn.evict_armed {
                        conn.evict_armed = true;
                        self.wheel
                            .insert(Instant::now() + EVICT_GRACE, TimerItem::EvictDeadline(tok));
                    }
                    return;
                }
                if conn.version.binary_frames() {
                    Self::next_frame(conn)
                } else {
                    Self::next_line(conn)
                }
            };
            match next {
                NextReq::None => return,
                NextReq::Refused(retry_ms) => {
                    self.daemon
                        .metrics
                        .shed_rate_limited
                        .fetch_add(1, Ordering::Relaxed);
                    let version = match self.slab.get_mut(tok) {
                        Some(conn) => conn.version,
                        None => return,
                    };
                    let resp = codec::render_response(
                        &Response::Error(ApiError::overloaded(
                            "connection request rate limit exceeded",
                            retry_ms,
                        )),
                        version,
                    );
                    self.queue_body(tok, &resp);
                    continue; // the next pipelined request may be in budget
                }
                NextReq::BadOpcode(op) => {
                    let resp = codec::render_response(
                        &Response::Error(ApiError::unsupported(format!(
                            "unknown v3 frame opcode {op:#04x}"
                        ))),
                        ProtocolVersion::V3,
                    );
                    self.queue_body(tok, &resp);
                    continue; // frame boundaries survive a bad opcode
                }
                NextReq::FrameError(e) => {
                    // The length prefix is garbage: everything after it is
                    // unframeable, so answer typed and hang up.
                    let resp =
                        codec::render_response(&Response::Error(e), ProtocolVersion::V3);
                    self.queue_body(tok, &resp);
                    self.close_token(tok);
                    return;
                }
                NextReq::Line(line) => {
                    self.dispatch_line(tok, line);
                    return;
                }
                NextReq::Manifest(parsed) => {
                    self.dispatch_msubmit_frame(tok, parsed);
                    return;
                }
            }
        }
    }

    /// Hand a request line to the worker pool; the outcome comes back
    /// through the completion queue.
    fn dispatch_line(&mut self, tok: u64, line: String) {
        let (version, chunks) = match self.slab.get_mut(tok) {
            Some(conn) => (conn.version, Arc::clone(&conn.chunks)),
            None => return,
        };
        self.comps.inflight.fetch_add(1, Ordering::SeqCst);
        let daemon = Arc::clone(&self.daemon);
        let comps = Arc::clone(&self.comps);
        // Stamped before the pool queue so a `deadline_ms=` budget
        // covers worker-queue time (see [`Daemon::handle_line_at`]).
        let arrived = Instant::now();
        self.pool.execute(move || {
            let outcome = {
                let mut asm = chunks.lock().expect("chunk assembler poisoned");
                daemon.handle_line_at(&line, version, Some(&mut asm), arrived)
            };
            comps
                .queue
                .lock()
                .expect("completion queue poisoned")
                .push((tok, Completion::Line(outcome)));
            // Decrement *after* the push so an observer seeing zero
            // in-flight knows the queue holds every outcome.
            comps.inflight.fetch_sub(1, Ordering::SeqCst);
            comps.waker.wake();
        });
    }

    /// Hand a reactor-parsed binary `MSUBMIT` to the worker pool; the
    /// response comes back as ready-to-send frame bytes.
    fn dispatch_msubmit_frame(&mut self, tok: u64, parsed: Result<Manifest, ApiError>) {
        let chunks = match self.slab.get_mut(tok) {
            Some(conn) => Arc::clone(&conn.chunks),
            None => return,
        };
        self.comps.inflight.fetch_add(1, Ordering::SeqCst);
        let daemon = Arc::clone(&self.daemon);
        let comps = Arc::clone(&self.comps);
        self.pool.execute(move || {
            let frame = {
                let mut asm = chunks.lock().expect("chunk assembler poisoned");
                daemon.handle_msubmit_frame(parsed, Some(&mut asm))
            };
            comps
                .queue
                .lock()
                .expect("completion queue poisoned")
                .push((tok, Completion::Frame(frame)));
            comps.inflight.fetch_sub(1, Ordering::SeqCst);
            comps.waker.wake();
        });
    }

    fn drain_completions(&mut self) {
        loop {
            let batch: Vec<(u64, Completion)> = {
                let mut q = self.comps.queue.lock().expect("completion queue poisoned");
                std::mem::take(&mut *q)
            };
            if batch.is_empty() {
                return;
            }
            for (tok, outcome) in batch {
                self.on_completion(tok, outcome);
            }
        }
    }

    fn on_completion(&mut self, tok: u64, comp: Completion) {
        let dead = match self.slab.get_mut(tok) {
            None => {
                // Busy slots are pinned, so this should be unreachable; a
                // parked outcome must still resolve exactly once.
                if let Completion::Line(LineOutcome::Parked(pw)) = comp {
                    let resp = self
                        .daemon
                        .poll_wait(&pw.ticket)
                        .unwrap_or_else(|| self.daemon.reject_wait(&pw.ticket, "connection closed"));
                    let _ = self.daemon.finish_wait(&pw, resp);
                }
                return;
            }
            Some(conn) => {
                conn.busy = false;
                conn.dead
            }
        };
        let outcome = match comp {
            Completion::Frame(bytes) => {
                // Binary responses arrive ready to send; nothing to render
                // and no negotiation can ride on a frame.
                if dead {
                    self.maybe_reap(tok);
                    return;
                }
                self.queue_frame(tok, &bytes);
                self.touch_idle(tok);
                self.maybe_close_eof(tok);
                return;
            }
            Completion::Line(outcome) => outcome,
        };
        match outcome {
            LineOutcome::Done(resp, negotiated) => {
                // Whether this response gets framed is decided by the wire
                // dialect the request arrived under — the `HELLO v3` ack
                // itself still goes out as text; only bytes *after* the
                // upgrade are framed.
                let framed = matches!(
                    self.slab.get_mut(tok),
                    Some(c) if c.version.binary_frames()
                );
                if let Some(v) = negotiated {
                    if let Some(conn) = self.slab.get_mut(tok) {
                        conn.version = v;
                    }
                }
                if dead {
                    self.maybe_reap(tok);
                    return;
                }
                if framed {
                    self.queue_frame(tok, &codec::v3_frame(codec::OP_TEXT_RESP, resp.as_bytes()));
                } else {
                    self.queue_response(tok, &resp);
                }
                self.touch_idle(tok);
                self.maybe_close_eof(tok);
            }
            LineOutcome::Parked(pw) => {
                if dead || self.shutting_down || self.parked_tokens.len() >= MAX_PARKED_WAITS {
                    // Resolve inline, exactly once: peer gone, shutting
                    // down, or registry back-pressure.
                    let why = if self.shutting_down {
                        "daemon is shutting down"
                    } else {
                        "too many concurrent WAITs"
                    };
                    let resp = self
                        .daemon
                        .poll_wait(&pw.ticket)
                        .unwrap_or_else(|| self.daemon.reject_wait(&pw.ticket, why));
                    let rendered = self.daemon.finish_wait(&pw, resp);
                    if dead {
                        self.maybe_reap(tok);
                    } else {
                        self.queue_body(tok, &rendered);
                        self.touch_idle(tok);
                        self.maybe_close_eof(tok);
                    }
                    return;
                }
                let deadline = pw.ticket.deadline;
                if let Some(conn) = self.slab.get_mut(tok) {
                    conn.parked = Some(pw);
                }
                self.parked_tokens.push(tok);
                self.sync_parked_gauge();
                self.wheel.insert(deadline, TimerItem::WaitDeadline(tok));
            }
        }
    }

    // ---- parked WAITs ------------------------------------------------------

    fn poll_parked(&mut self) {
        for tok in self.parked_tokens.clone() {
            self.resolve_parked(tok);
        }
    }

    /// Resolve one parked wait if the daemon can answer it now (settled,
    /// deadline passed, or shutdown); otherwise leave it parked.
    fn resolve_parked(&mut self, tok: u64) {
        let answer = {
            let Some(conn) = self.slab.get_mut(tok) else {
                self.forget_parked(tok);
                return;
            };
            let Some(pw) = conn.parked.as_ref() else {
                self.forget_parked(tok);
                return;
            };
            match self.daemon.poll_wait(&pw.ticket) {
                None => return, // not answerable yet
                Some(resp) => {
                    let pw = conn.parked.take().expect("checked above");
                    (pw, resp, conn.dead)
                }
            }
        };
        let (pw, resp, dead) = answer;
        self.forget_parked(tok);
        let rendered = self.daemon.finish_wait(&pw, resp);
        if dead {
            self.maybe_reap(tok);
        } else {
            self.queue_body(tok, &rendered);
            self.touch_idle(tok);
            // The connection resumes normal service (pipelined requests
            // buffered behind the WAIT included).
            self.maybe_close_eof(tok);
        }
    }

    fn forget_parked(&mut self, tok: u64) {
        if let Some(i) = self.parked_tokens.iter().position(|&t| t == tok) {
            self.parked_tokens.swap_remove(i);
            self.sync_parked_gauge();
        }
    }

    // ---- timers ------------------------------------------------------------

    fn fire_timers(&mut self) {
        let now = Instant::now();
        let mut due = Vec::new();
        self.wheel.expire(now, |item| due.push(item));
        if !due.is_empty() {
            self.shard
                .timers_fired
                .fetch_add(due.len() as u64, Ordering::Relaxed);
        }
        for item in due {
            match item {
                TimerItem::Idle(tok) => self.on_idle_timer(tok, now),
                TimerItem::WaitDeadline(tok) => self.resolve_parked(tok),
                TimerItem::AcceptRetry => {
                    self.accept_paused_until = None;
                    self.drain_accept();
                }
                TimerItem::EvictDeadline(tok) => self.on_evict_timer(tok),
            }
        }
    }

    /// The eviction deadline fired: a connection still pinned at the
    /// write-backlog cap is a slow consumer — close it, count it, and let
    /// the drop free its buffered responses. A backlog that drained in
    /// the meantime just disarms (a later pin re-arms a fresh grace).
    fn on_evict_timer(&mut self, tok: u64) {
        let evict = match self.slab.get_mut(tok) {
            None => return, // slot freed or reused: stale entry
            Some(conn) => {
                conn.evict_armed = false;
                !conn.dead && conn.write_buf.len() - conn.write_pos > MAX_WRITE_BACKLOG
            }
        };
        if evict {
            self.daemon
                .metrics
                .conns_evicted
                .fetch_add(1, Ordering::Relaxed);
            self.shard.evictions.fetch_add(1, Ordering::Relaxed);
            self.close_token(tok);
        }
    }

    fn on_idle_timer(&mut self, tok: u64, now: Instant) {
        enum Act {
            Close,
            Rearm(Instant),
            Nothing,
        }
        let act = match self.slab.get_mut(tok) {
            None => Act::Nothing, // slot freed or reused: stale entry
            Some(conn) => {
                conn.idle_timer_armed = false;
                if conn.dead {
                    Act::Nothing
                } else if conn.busy || conn.parked.is_some() {
                    // Handling / parked time is not idle time.
                    conn.idle_deadline = now + self.idle_timeout;
                    conn.idle_timer_armed = true;
                    Act::Rearm(conn.idle_deadline)
                } else if now < conn.idle_deadline {
                    conn.idle_timer_armed = true;
                    Act::Rearm(conn.idle_deadline)
                } else {
                    Act::Close
                }
            }
        };
        match act {
            Act::Close => self.close_token(tok),
            Act::Rearm(dl) => self.wheel.insert(dl, TimerItem::Idle(tok)),
            Act::Nothing => {}
        }
    }

    /// Push the idle deadline out; lazily (re-)arm the wheel entry.
    fn touch_idle(&mut self, tok: u64) {
        let timeout = self.idle_timeout;
        let mut arm: Option<Instant> = None;
        if let Some(conn) = self.slab.get_mut(tok) {
            conn.idle_deadline = Instant::now() + timeout;
            if !conn.idle_timer_armed {
                conn.idle_timer_armed = true;
                arm = Some(conn.idle_deadline);
            }
        }
        if let Some(dl) = arm {
            self.wheel.insert(dl, TimerItem::Idle(tok));
        }
    }

    // ---- writes and closing ------------------------------------------------

    fn queue_response(&mut self, tok: u64, body: &str) {
        if let Some(conn) = self.slab.get_mut(tok) {
            conn.write_buf.extend_from_slice(body.as_bytes());
            conn.write_buf.extend_from_slice(b"\n\n");
        }
        self.try_flush(tok);
    }

    /// Queue ready-to-send v3 frame bytes. No terminator: the length
    /// prefix is the delimiter.
    fn queue_frame(&mut self, tok: u64, frame: &[u8]) {
        if let Some(conn) = self.slab.get_mut(tok) {
            conn.write_buf.extend_from_slice(frame);
        }
        self.try_flush(tok);
    }

    /// Queue a rendered response body in the connection's wire dialect:
    /// framed (`OP_TEXT_RESP`) after a v3 upgrade, blank-line-terminated
    /// text before. Used wherever a response is produced away from the
    /// request that triggered it (rate refusals, parked `WAIT`
    /// resolutions, shutdown notices).
    fn queue_body(&mut self, tok: u64, body: &str) {
        let framed = matches!(
            self.slab.get_mut(tok),
            Some(c) if c.version.binary_frames()
        );
        if framed {
            self.queue_frame(tok, &codec::v3_frame(codec::OP_TEXT_RESP, body.as_bytes()));
        } else {
            self.queue_response(tok, body);
        }
    }

    fn try_flush(&mut self, tok: u64) {
        enum After {
            None,
            Close,
            ArmOut(RawFd),
            DisarmOut(RawFd),
        }
        let mut after = After::None;
        let mut first_byte_ns: Option<u64> = None;
        if let Some(conn) = self.slab.get_mut(tok) {
            while conn.write_pos < conn.write_buf.len() {
                match conn.stream.write(&conn.write_buf[conn.write_pos..]) {
                    Ok(0) => {
                        after = After::Close;
                        break;
                    }
                    Ok(n) => {
                        if !conn.first_byte_sent {
                            conn.first_byte_sent = true;
                            first_byte_ns = Some(conn.accepted_at.elapsed().as_nanos() as u64);
                        }
                        conn.write_pos += n;
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        if !conn.wants_write {
                            conn.wants_write = true;
                            after = After::ArmOut(conn.stream.as_raw_fd());
                        }
                        break;
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        after = After::Close;
                        break;
                    }
                }
            }
            if matches!(after, After::None) && conn.write_pos >= conn.write_buf.len() {
                conn.write_buf.clear();
                if conn.write_buf.capacity() > BUF_SHRINK_THRESHOLD {
                    conn.write_buf.shrink_to(READ_CHUNK);
                }
                conn.write_pos = 0;
                if conn.wants_write {
                    conn.wants_write = false;
                    after = After::DisarmOut(conn.stream.as_raw_fd());
                }
            }
        }
        if let Some(ns) = first_byte_ns {
            self.daemon.metrics.record_accept_to_first_byte(ns);
        }
        match after {
            After::None => {}
            After::Close => self.close_token(tok),
            After::ArmOut(fd) => {
                let _ = self
                    .epoll
                    .ctl(EPOLL_CTL_MOD, fd, EPOLLIN | EPOLLOUT | EPOLLET, tok);
            }
            After::DisarmOut(fd) => {
                let _ = self.epoll.ctl(EPOLL_CTL_MOD, fd, EPOLLIN | EPOLLET, tok);
            }
        }
    }

    /// Close a connection. Slots with in-flight or parked work linger
    /// (marked dead) until that work resolves, so completions and wait
    /// resolutions stay exactly-once; dropping the `TcpStream` closes the
    /// fd, which also deregisters it from epoll.
    fn close_token(&mut self, tok: u64) {
        let defer = match self.slab.get_mut(tok) {
            None => return,
            Some(conn) => {
                if conn.busy || conn.parked.is_some() {
                    conn.dead = true;
                    true
                } else {
                    false
                }
            }
        };
        if !defer {
            self.remove_conn(tok);
        }
    }

    /// Reap a dead slot once nothing references it anymore.
    fn maybe_reap(&mut self, tok: u64) {
        let reap = matches!(
            self.slab.get_mut(tok),
            Some(c) if c.dead && !c.busy && c.parked.is_none()
        );
        if reap {
            self.remove_conn(tok);
        }
    }

    // ---- shutdown ----------------------------------------------------------

    fn cleanup(&mut self) {
        self.shutting_down = true;
        // Let in-flight requests land so their responses (the SHUTDOWN ack
        // among them) reach their sockets.
        let deadline = Instant::now() + Duration::from_secs(5);
        while self.comps.inflight.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        self.drain_completions();
        // Resolve still-parked waits exactly once (settled or a typed
        // shutdown error) so no client hangs on a dead socket.
        for tok in std::mem::take(&mut self.parked_tokens) {
            let taken = self.slab.get_mut(tok).and_then(|c| c.parked.take());
            if let Some(pw) = taken {
                let resp = self.daemon.poll_wait(&pw.ticket).unwrap_or_else(|| {
                    self.daemon.reject_wait(&pw.ticket, "daemon is shutting down")
                });
                let rendered = self.daemon.finish_wait(&pw, resp);
                self.queue_body(tok, &rendered);
            }
        }
        self.sync_parked_gauge();
        // Flush queued responses until they drain or a bounded deadline —
        // a single nonblocking attempt would drop the SHUTDOWN ack (or a
        // resolved WAIT's reply) on the floor whenever the socket buffer
        // pushed back, breaking the "responses are flushed" shutdown
        // contract. Everything drops (and closes) with self afterwards.
        let flush_deadline = Instant::now() + Duration::from_secs(2);
        loop {
            let mut pending = false;
            for tok in self.slab.tokens() {
                self.try_flush(tok);
                if let Some(conn) = self.slab.get_mut(tok) {
                    if !conn.dead && conn.write_pos < conn.write_buf.len() {
                        pending = true;
                    }
                }
            }
            if !pending || Instant::now() >= flush_deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuseport_listeners_share_one_port_and_accept() {
        let ls = reuseport_listeners(SocketAddrV4::new(Ipv4Addr::LOCALHOST, 0), 3).unwrap();
        assert_eq!(ls.len(), 3);
        let port = ls[0].local_addr().unwrap().port();
        assert_ne!(port, 0, "port 0 must resolve on the first listener");
        for l in &ls {
            assert_eq!(l.local_addr().unwrap().port(), port);
        }
        // The kernel picks the shard per connection; drain across all
        // listeners until every connection has been accepted somewhere.
        let n_conns = 8;
        let _streams: Vec<_> = (0..n_conns)
            .map(|_| TcpStream::connect(("127.0.0.1", port)).unwrap())
            .collect();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut accepted = 0;
        while accepted < n_conns && Instant::now() < deadline {
            let mut any = false;
            for l in &ls {
                while l.accept().is_ok() {
                    accepted += 1;
                    any = true;
                }
            }
            if !any {
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        assert_eq!(accepted, n_conns, "every connection reaches some shard");
    }

    #[test]
    fn tokens_roundtrip() {
        let t = token(7, 42);
        assert_eq!(token_idx(t), 7);
        assert_eq!(token_gen(t), 42);
        assert_ne!(t, TOKEN_LISTENER);
        assert_ne!(t, TOKEN_WAKER);
    }

    #[test]
    fn slab_generation_invalidates_stale_tokens() {
        fn conn_stub() -> Conn {
            // A connected-but-unused socket pair via a loopback listener.
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let stream = TcpStream::connect(l.local_addr().unwrap()).unwrap();
            let now = Instant::now();
            Conn {
                stream,
                read_buf: Vec::new(),
                read_pos: 0,
                scan_pos: 0,
                write_buf: Vec::new(),
                write_pos: 0,
                version: ProtocolVersion::V1,
                chunks: Arc::new(Mutex::new(ChunkAssembler::new())),
                busy: false,
                parked: None,
                dead: false,
                peer_eof: false,
                wants_write: false,
                idle_deadline: now,
                idle_timer_armed: false,
                accepted_at: now,
                first_byte_sent: false,
                bucket: None,
                evict_armed: false,
            }
        }
        let mut slab = Slab::default();
        let t1 = slab.insert(conn_stub());
        assert!(slab.get_mut(t1).is_some());
        assert!(slab.remove(t1).is_some());
        assert!(slab.get_mut(t1).is_none(), "freed token must not resolve");
        let t2 = slab.insert(conn_stub());
        assert_eq!(token_idx(t1), token_idx(t2), "slot reused");
        assert_ne!(t1, t2, "generation must differ");
        assert!(slab.get_mut(t1).is_none(), "stale token must not resolve");
        assert!(slab.get_mut(t2).is_some());
    }

    #[test]
    fn take_line_handles_partials_and_crlf() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let now = Instant::now();
        let mut conn = Conn {
            stream,
            read_buf: Vec::new(),
            read_pos: 0,
            scan_pos: 0,
            write_buf: Vec::new(),
            write_pos: 0,
            version: ProtocolVersion::V1,
            chunks: Arc::new(Mutex::new(ChunkAssembler::new())),
            busy: false,
            parked: None,
            dead: false,
            peer_eof: false,
            wants_write: false,
            idle_deadline: now,
            idle_timer_armed: false,
            accepted_at: now,
            first_byte_sent: false,
            bucket: None,
            evict_armed: false,
        };
        conn.read_buf.extend_from_slice(b"PI");
        assert!(conn.take_line().is_none());
        conn.read_buf.extend_from_slice(b"NG\r\nUT");
        assert_eq!(conn.take_line().as_deref(), Some("PING"));
        assert!(conn.take_line().is_none());
        conn.read_buf.extend_from_slice(b"IL\n");
        assert_eq!(conn.take_line().as_deref(), Some("UTIL"));
        assert!(conn.take_line().is_none());
        assert!(conn.read_buf.is_empty());

        // Deep pipelined backlog: every line extracted intact and the
        // consumed prefix is compacted away (bounded buffer, no O(N²)).
        for _ in 0..2000 {
            conn.read_buf.extend_from_slice(b"PING\n");
        }
        let mut n = 0;
        for _ in 0..1000 {
            assert_eq!(conn.take_line().as_deref(), Some("PING"));
            n += 1;
        }
        assert!(
            conn.read_buf.len() < 6_000,
            "consumed prefix never compacted ({} bytes retained)",
            conn.read_buf.len()
        );
        while let Some(l) = conn.take_line() {
            assert_eq!(l, "PING");
            n += 1;
        }
        assert_eq!(n, 2000);
        assert!(conn.read_buf.is_empty());
    }

    fn v3_conn_stub() -> Conn {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let now = Instant::now();
        Conn {
            stream,
            read_buf: Vec::new(),
            read_pos: 0,
            scan_pos: 0,
            write_buf: Vec::new(),
            write_pos: 0,
            version: ProtocolVersion::V3,
            chunks: Arc::new(Mutex::new(ChunkAssembler::new())),
            busy: false,
            parked: None,
            dead: false,
            peer_eof: false,
            wants_write: false,
            idle_deadline: now,
            idle_timer_armed: false,
            accepted_at: now,
            first_byte_sent: false,
            bucket: None,
            evict_armed: false,
        }
    }

    #[test]
    fn frame_extraction_peeks_consumes_and_rejects_bad_prefixes() {
        let mut conn = v3_conn_stub();
        // A frame arriving in dribbles is not extractable until complete.
        let frame = codec::v3_frame(codec::OP_TEXT_REQ, b"PING");
        conn.read_buf.extend_from_slice(&frame[..3]);
        assert!(matches!(conn.peek_frame(), Ok(None)));
        conn.read_buf.extend_from_slice(&frame[3..frame.len() - 1]);
        assert!(matches!(conn.peek_frame(), Ok(None)));
        conn.read_buf.extend_from_slice(&frame[frame.len() - 1..]);
        let (opcode, start, end) = conn.peek_frame().unwrap().unwrap();
        assert_eq!(opcode, codec::OP_TEXT_REQ);
        assert_eq!(&conn.read_buf[start..end], b"PING");
        conn.consume_to(end);
        assert!(conn.read_buf.is_empty(), "fully consumed buffer resets");

        // Two pipelined frames extract in order, each exactly once.
        conn.read_buf
            .extend_from_slice(&codec::v3_frame(codec::OP_MSUBMIT, b"\x01"));
        conn.read_buf
            .extend_from_slice(&codec::v3_frame(codec::OP_TEXT_REQ, b"UTIL"));
        let (op1, s1, e1) = conn.peek_frame().unwrap().unwrap();
        assert_eq!(op1, codec::OP_MSUBMIT);
        assert_eq!(&conn.read_buf[s1..e1], b"\x01");
        conn.consume_to(e1);
        let (op2, s2, e2) = conn.peek_frame().unwrap().unwrap();
        assert_eq!(op2, codec::OP_TEXT_REQ);
        assert_eq!(&conn.read_buf[s2..e2], b"UTIL");
        conn.consume_to(e2);
        assert!(conn.read_buf.is_empty());

        // A zero or oversized length prefix can never resync: typed error.
        conn.read_buf.extend_from_slice(&0u32.to_le_bytes());
        assert!(conn.peek_frame().is_err());
        conn.read_buf.clear();
        let huge = (codec::MAX_FRAME_BYTES as u32) + 1;
        conn.read_buf.extend_from_slice(&huge.to_le_bytes());
        assert!(conn.peek_frame().is_err());
    }
}
