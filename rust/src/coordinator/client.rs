//! Blocking typed client for the daemon's TCP protocol.
//!
//! [`Client::connect`] starts a v1 session (wire-compatible with the seed
//! daemon); [`Client::connect_v2`] negotiates the v2 tagged grammar with
//! `HELLO v2`. The typed methods ([`Client::submit`], [`Client::squeue`],
//! [`Client::wait`], …) render requests and parse responses through
//! [`super::codec`], returning the payload structs from [`super::api`] —
//! `ERR` responses surface as [`ClientError::Api`] with a typed
//! [`ErrorCode`](super::api::ErrorCode), never as `Ok(String)`.

use super::api::{
    ApiError, JobDetail, JobSummary, ProtocolVersion, Request, Response, SqueueFilter,
    StatsSnapshot, SubmitAck, SubmitSpec, UtilSnapshot, WaitResult,
};
use super::codec;
use super::manifest::{Manifest, ManifestAck};
use std::fmt;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Default socket read/write timeout.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Client-side failures.
#[derive(Debug)]
pub enum ClientError {
    /// Transport failure.
    Io(std::io::Error),
    /// The daemon answered with a typed protocol error.
    Api(ApiError),
    /// The daemon answered something this client could not interpret.
    Protocol(String),
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Api(e) => write!(f, "{e}"),
            ClientError::Protocol(msg) => write!(f, "protocol: {msg}"),
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Io(e) => Some(e),
            ClientError::Api(e) => Some(e),
            ClientError::Protocol(_) => None,
        }
    }
}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

/// `Result` specialized to [`ClientError`].
pub type ClientResult<T> = Result<T, ClientError>;

/// A connected client.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    version: ProtocolVersion,
}

impl Client {
    /// Connect to `host:port`, speaking protocol v1 (upgrade with
    /// [`Client::hello`]).
    pub fn connect(addr: &str) -> ClientResult<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(DEFAULT_IO_TIMEOUT))?;
        stream.set_write_timeout(Some(DEFAULT_IO_TIMEOUT))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self {
            writer: stream,
            reader,
            version: ProtocolVersion::V1,
        })
    }

    /// Connect and negotiate protocol v2.
    pub fn connect_v2(addr: &str) -> ClientResult<Self> {
        let mut c = Self::connect(addr)?;
        c.hello(ProtocolVersion::V2)?;
        Ok(c)
    }

    /// The protocol version this session speaks.
    pub fn version(&self) -> ProtocolVersion {
        self.version
    }

    /// Send one raw request line, read the raw response (terminated by a
    /// blank line). Returns the response body without the terminator.
    /// Escape hatch for ad-hoc lines; the typed methods below are preferred.
    pub fn request(&mut self, line: &str) -> ClientResult<String> {
        self.send_line(line)?;
        self.read_response()
    }

    fn send_line(&mut self, line: &str) -> ClientResult<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    fn read_response(&mut self) -> ClientResult<String> {
        let mut out = String::new();
        loop {
            let mut buf = String::new();
            let n = self.reader.read_line(&mut buf)?;
            if n == 0 {
                return Err(ClientError::Protocol("server closed the connection".into()));
            }
            if buf == "\n" {
                break;
            }
            out.push_str(&buf);
        }
        Ok(out.trim_end_matches('\n').to_string())
    }

    /// One typed round trip. `ERR` responses come back as
    /// [`ClientError::Api`].
    fn roundtrip(&mut self, req: &Request) -> ClientResult<Response> {
        let line = codec::render_request(req, self.version);
        self.send_line(&line)?;
        let raw = self.read_response()?;
        // A HELLO response is rendered in the *negotiated* version.
        let parse_version = match req {
            Request::Hello(v) => *v,
            _ => self.version,
        };
        match codec::parse_response(&raw, parse_version) {
            Ok(Response::Error(e)) => Err(ClientError::Api(e)),
            Ok(resp) => Ok(resp),
            Err(e) => Err(ClientError::Protocol(format!(
                "unparseable response {raw:?}: {e}"
            ))),
        }
    }

    /// Pipeline several requests over this connection: write every request
    /// line back-to-back, then read the responses, which the server
    /// guarantees arrive **in request order**. One round-trip's latency is
    /// paid once for the whole batch instead of once per request — the
    /// launcher-loop pattern ("submit, submit, …, stats") without N × RTT.
    ///
    /// Unlike the single-request helpers, `ERR` responses come back as
    /// [`Response::Error`] variants in the result vector (a failed request
    /// must not hide the responses behind it); transport failures are still
    /// `Err`. `HELLO` cannot be pipelined — it changes the wire version
    /// mid-stream, making the remaining responses unparseable.
    pub fn pipeline(&mut self, reqs: &[Request]) -> ClientResult<Vec<Response>> {
        if reqs.iter().any(|r| matches!(r, Request::Hello(_))) {
            return Err(ClientError::Protocol(
                "HELLO cannot be pipelined (it renegotiates the wire version)".into(),
            ));
        }
        for r in reqs {
            if let Request::MSubmit(m) = r {
                if let Some((i, tag)) = m.first_unframeable_tag() {
                    return Err(ClientError::Protocol(format!(
                        "manifest entry {i} has a tag that cannot be framed on the wire: {tag:?}"
                    )));
                }
            }
        }
        let mut batch = String::new();
        for req in reqs {
            batch.push_str(&codec::render_request(req, self.version));
            batch.push('\n');
        }
        self.writer.write_all(batch.as_bytes())?;
        self.writer.flush()?;
        let mut out = Vec::with_capacity(reqs.len());
        for _ in reqs {
            let raw = self.read_response()?;
            match codec::parse_response(&raw, self.version) {
                Ok(resp) => out.push(resp),
                Err(e) => {
                    return Err(ClientError::Protocol(format!(
                        "unparseable response {raw:?}: {e}"
                    )))
                }
            }
        }
        Ok(out)
    }

    /// Negotiate the protocol version for this connection.
    pub fn hello(&mut self, version: ProtocolVersion) -> ClientResult<ProtocolVersion> {
        match self.roundtrip(&Request::Hello(version))? {
            Response::Hello(v) => {
                self.version = v;
                Ok(v)
            }
            other => Err(unexpected("HELLO", &other)),
        }
    }

    /// Liveness check.
    pub fn ping(&mut self) -> ClientResult<()> {
        match self.roundtrip(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(unexpected("PING", &other)),
        }
    }

    /// Submit a (possibly batched) spec; returns the assigned id range.
    pub fn submit(&mut self, spec: &SubmitSpec) -> ClientResult<SubmitAck> {
        match self.roundtrip(&Request::Submit(spec.clone()))? {
            Response::SubmitAck(ack) => Ok(ack),
            other => Err(unexpected("SUBMIT", &other)),
        }
    }

    /// Submit a heterogeneous manifest in one RPC; returns per-entry job-id
    /// ranges and typed per-entry rejects (partial accept — a reject does
    /// not fail the call). Requires a v2 session: the v1 grammar cannot
    /// express a manifest, and the daemon would answer `unsupported`.
    pub fn msubmit(&mut self, manifest: &Manifest) -> ClientResult<ManifestAck> {
        if self.version != ProtocolVersion::V2 {
            return Err(ClientError::Protocol(
                "MSUBMIT requires protocol v2 (connect with Client::connect_v2)".into(),
            ));
        }
        // A tag with whitespace/`;`/newline would corrupt the single-line
        // record framing (a newline would even inject a second request):
        // refuse before any byte goes out.
        if let Some((i, tag)) = manifest.first_unframeable_tag() {
            return Err(ClientError::Protocol(format!(
                "manifest entry {i} has a tag that cannot be framed on the wire: {tag:?}"
            )));
        }
        match self.roundtrip(&Request::MSubmit(manifest.clone()))? {
            Response::ManifestAck(ack) => Ok(ack),
            other => Err(unexpected("MSUBMIT", &other)),
        }
    }

    /// List jobs matching `filter`.
    pub fn squeue(&mut self, filter: &SqueueFilter) -> ClientResult<Vec<JobSummary>> {
        match self.roundtrip(&Request::Squeue(filter.clone()))? {
            Response::Jobs(rows) => Ok(rows),
            other => Err(unexpected("SQUEUE", &other)),
        }
    }

    /// Detail for one job.
    pub fn job(&mut self, id: u64) -> ClientResult<JobDetail> {
        match self.roundtrip(&Request::Sjob(id))? {
            Response::Job(d) => Ok(d),
            other => Err(unexpected("SJOB", &other)),
        }
    }

    /// Cancel a job; `Err(ClientError::Api)` with `NotFound` when unknown.
    pub fn cancel(&mut self, id: u64) -> ClientResult<u64> {
        match self.roundtrip(&Request::Scancel(id))? {
            Response::Cancelled(id) => Ok(id),
            other => Err(unexpected("SCANCEL", &other)),
        }
    }

    /// Block until `jobs` have all dispatched (or `timeout_secs` of wall
    /// time elapse) and return the burst's virtual scheduling latency — the
    /// paper's launch-latency measurement, end to end from a remote client.
    pub fn wait(&mut self, jobs: &[u64], timeout_secs: f64) -> ClientResult<WaitResult> {
        // An empty set is settled by definition, and the v1 grammar cannot
        // even express it — short-circuit without a round trip.
        if jobs.is_empty() {
            return Ok(WaitResult {
                requested: 0,
                dispatched: 0,
                timed_out: false,
                latency_ns: 0,
            });
        }
        // The daemon blocks up to timeout_secs; give the socket headroom.
        let io_timeout = Duration::from_secs_f64(timeout_secs.max(0.0) + 30.0);
        self.writer.set_read_timeout(Some(io_timeout))?;
        let result = self.roundtrip(&Request::Wait {
            jobs: jobs.to_vec(),
            timeout_secs,
        });
        self.writer.set_read_timeout(Some(DEFAULT_IO_TIMEOUT))?;
        match result? {
            Response::Wait(w) => Ok(w),
            other => Err(unexpected("WAIT", &other)),
        }
    }

    /// Daemon + scheduler counters.
    pub fn stats(&mut self) -> ClientResult<StatsSnapshot> {
        match self.roundtrip(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(unexpected("STATS", &other)),
        }
    }

    /// Cluster utilization snapshot.
    pub fn util(&mut self) -> ClientResult<UtilSnapshot> {
        match self.roundtrip(&Request::Util)? {
            Response::Util(u) => Ok(u),
            other => Err(unexpected("UTIL", &other)),
        }
    }

    /// Ask the daemon to stop.
    pub fn shutdown(&mut self) -> ClientResult<()> {
        match self.roundtrip(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => Err(unexpected("SHUTDOWN", &other)),
        }
    }
}

fn unexpected(cmd: &str, resp: &Response) -> ClientError {
    ClientError::Protocol(format!("unexpected response to {cmd}: {resp:?}"))
}
