//! Blocking client for the daemon's TCP protocol.

use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// A connected client.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to `host:port`.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .context("read timeout")?;
        let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(Self {
            writer: stream,
            reader,
        })
    }

    /// Send one request line, read the response (terminated by a blank
    /// line). Returns the response without the terminator.
    pub fn request(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut out = String::new();
        loop {
            let mut buf = String::new();
            let n = self.reader.read_line(&mut buf)?;
            anyhow::ensure!(n > 0, "server closed the connection");
            if buf == "\n" {
                break;
            }
            out.push_str(&buf);
        }
        Ok(out.trim_end_matches('\n').to_string())
    }
}
