//! Blocking typed client for the daemon's TCP protocol.
//!
//! [`Client::connect`] starts a v1 session (wire-compatible with the seed
//! daemon); [`Client::connect_v2`] negotiates the v2 tagged grammar with
//! `HELLO v2`, [`Client::connect_v21`] negotiates v2.1, which adds the
//! chunked `MSUBMIT` stream ([`Client::msubmit_chunked`]), and
//! [`Client::connect_v3`] negotiates the v3 binary framing: requests and
//! responses travel in length-prefixed frames, and `MSUBMIT` manifests go
//! out varint-packed instead of as text records. The typed
//! methods ([`Client::submit`], [`Client::squeue`], [`Client::wait`], …)
//! render requests and parse responses through [`super::codec`], returning
//! the payload structs from [`super::api`] — `ERR` responses surface as
//! [`ClientError::Api`] with a typed
//! [`ErrorCode`](super::api::ErrorCode), never as `Ok(String)`.

use super::api::{
    ApiError, ErrorCode, HealthReport, JobDetail, JobSummary, ProtocolVersion, Request, Response,
    ResumeInfo, ResumeTarget, SqueueFilter, StatsSnapshot, SubmitAck, SubmitSpec, UtilSnapshot,
    WaitResult,
};
use super::codec;
use super::manifest::{
    Manifest, ManifestAck, ManifestChunk, MAX_CHUNKED_MANIFEST_ENTRIES, MAX_CHUNK_PARTS,
};
use crate::util::rng::Xoshiro256;
use std::fmt;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Default socket read/write timeout.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Retry/backoff schedule for reconnecting to a daemon that is down —
/// typically one that crashed and is being recovered from its journal.
///
/// Delays grow exponentially from `base_delay` (doubling per attempt,
/// capped at `max_delay`) with multiplicative jitter in `[0.5, 1.0]` so a
/// fleet of resuming launchers does not reconnect in lockstep. The jitter
/// stream is seeded deterministically (`seed`), keeping tests reproducible.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// Total connection attempts (the first try counts; 0 behaves as 1).
    pub attempts: u32,
    /// Delay before the second attempt (doubles each retry).
    pub base_delay: Duration,
    /// Upper bound on any single delay, pre-jitter.
    pub max_delay: Duration,
    /// Jitter seed.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            attempts: 5,
            base_delay: Duration::from_millis(100),
            max_delay: Duration::from_secs(5),
            seed: 0x5eed_cafe,
        }
    }
}

impl RetryPolicy {
    /// A no-backoff policy: one attempt, fail fast.
    pub fn once() -> Self {
        Self {
            attempts: 1,
            ..Self::default()
        }
    }

    /// The jittered delay to sleep after failed attempt `attempt`
    /// (0-based). Exponential: `min(max_delay, base_delay << attempt)`,
    /// scaled by a jitter factor in `[0.5, 1.0]`.
    pub fn delay_after(&self, attempt: u32, rng: &mut Xoshiro256) -> Duration {
        let exp = self
            .base_delay
            .saturating_mul(1u32 << attempt.min(16))
            .min(self.max_delay);
        exp.mul_f64(0.5 + 0.5 * rng.next_f64())
    }

    /// Run `connect` until it succeeds or the attempts are exhausted,
    /// sleeping the jittered backoff between tries. Transport
    /// ([`ClientError::Io`]) failures retry, as does the typed
    /// [`ErrorCode::Overloaded`] shed response — sleeping the daemon's
    /// `retry_after_ms` hint when it carries one, the jittered backoff
    /// otherwise. Any other typed API or protocol error means the daemon
    /// *is* up and deliberately refused: retrying would just repeat it.
    pub fn run<T>(
        &self,
        mut connect: impl FnMut() -> ClientResult<T>,
    ) -> ClientResult<T> {
        let mut rng = Xoshiro256::new(self.seed);
        let attempts = self.attempts.max(1);
        let mut last = None;
        for attempt in 0..attempts {
            let hint = match connect() {
                Ok(v) => return Ok(v),
                Err(e @ ClientError::Io(_)) => {
                    last = Some(e);
                    None
                }
                Err(ClientError::Api(e)) if e.code == ErrorCode::Overloaded => {
                    let hint = e.retry_after_ms.map(Duration::from_millis);
                    last = Some(ClientError::Api(e));
                    hint
                }
                Err(e) => return Err(e),
            };
            if attempt + 1 < attempts {
                let delay = hint.unwrap_or_else(|| self.delay_after(attempt, &mut rng));
                std::thread::sleep(delay);
            }
        }
        Err(last.expect("at least one attempt ran"))
    }
}

/// Client-side failures.
#[derive(Debug)]
pub enum ClientError {
    /// Transport failure.
    Io(std::io::Error),
    /// The daemon answered with a typed protocol error.
    Api(ApiError),
    /// The daemon answered something this client could not interpret.
    Protocol(String),
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Api(e) => write!(f, "{e}"),
            ClientError::Protocol(msg) => write!(f, "protocol: {msg}"),
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Io(e) => Some(e),
            ClientError::Api(e) => Some(e),
            ClientError::Protocol(_) => None,
        }
    }
}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

/// `Result` specialized to [`ClientError`].
pub type ClientResult<T> = Result<T, ClientError>;

/// A connected client.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    version: ProtocolVersion,
}

impl Client {
    /// Connect to `host:port`, speaking protocol v1 (upgrade with
    /// [`Client::hello`]).
    pub fn connect(addr: &str) -> ClientResult<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(DEFAULT_IO_TIMEOUT))?;
        stream.set_write_timeout(Some(DEFAULT_IO_TIMEOUT))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self {
            writer: stream,
            reader,
            version: ProtocolVersion::V1,
        })
    }

    /// Connect and negotiate protocol v2.
    pub fn connect_v2(addr: &str) -> ClientResult<Self> {
        let mut c = Self::connect(addr)?;
        c.hello(ProtocolVersion::V2)?;
        Ok(c)
    }

    /// Connect and negotiate protocol v2.1 (v2 plus the chunked `MSUBMIT`
    /// stream, [`Client::msubmit_chunked`]).
    pub fn connect_v21(addr: &str) -> ClientResult<Self> {
        let mut c = Self::connect(addr)?;
        c.hello(ProtocolVersion::V21)?;
        Ok(c)
    }

    /// Connect and negotiate protocol v3: the `HELLO`/ack exchange happens
    /// in text, then every subsequent request and response travels in
    /// length-prefixed binary frames ([`super::codec::decode_frame_header`]).
    pub fn connect_v3(addr: &str) -> ClientResult<Self> {
        let mut c = Self::connect(addr)?;
        c.hello(ProtocolVersion::V3)?;
        Ok(c)
    }

    /// Connect with retry/backoff — the resume path after a daemon crash:
    /// keep trying while the daemon restarts and replays its journal.
    pub fn connect_retry(addr: &str, policy: &RetryPolicy) -> ClientResult<Self> {
        policy.run(|| Self::connect(addr))
    }

    /// [`Client::connect_retry`], negotiating protocol v2.
    pub fn connect_v2_retry(addr: &str, policy: &RetryPolicy) -> ClientResult<Self> {
        policy.run(|| Self::connect_v2(addr))
    }

    /// [`Client::connect_retry`], negotiating protocol v2.1.
    pub fn connect_v21_retry(addr: &str, policy: &RetryPolicy) -> ClientResult<Self> {
        policy.run(|| Self::connect_v21(addr))
    }

    /// [`Client::connect_retry`], negotiating protocol v3.
    pub fn connect_v3_retry(addr: &str, policy: &RetryPolicy) -> ClientResult<Self> {
        policy.run(|| Self::connect_v3(addr))
    }

    /// The protocol version this session speaks.
    pub fn version(&self) -> ProtocolVersion {
        self.version
    }

    /// Send one raw request line, read the raw response (terminated by a
    /// blank line). Returns the response body without the terminator.
    /// Escape hatch for ad-hoc lines; the typed methods below are preferred.
    pub fn request(&mut self, line: &str) -> ClientResult<String> {
        self.send_line(line)?;
        self.read_response()
    }

    fn send_line(&mut self, line: &str) -> ClientResult<()> {
        if self.version.binary_frames() {
            return self.send_frame(codec::OP_TEXT_REQ, line.as_bytes());
        }
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    /// Write one v3 frame: `[len][opcode][payload]`.
    fn send_frame(&mut self, opcode: u8, payload: &[u8]) -> ClientResult<()> {
        self.writer.write_all(&codec::v3_frame(opcode, payload))?;
        self.writer.flush()?;
        Ok(())
    }

    /// Read one v3 frame, returning `(opcode, payload)`.
    fn read_frame(&mut self) -> ClientResult<(u8, Vec<u8>)> {
        let mut header = [0u8; codec::FRAME_HEADER_BYTES];
        self.reader.read_exact(&mut header)?;
        let len = match codec::decode_frame_header(&header) {
            Ok(Some(len)) => len,
            Ok(None) => {
                return Err(ClientError::Protocol("short frame header from server".into()));
            }
            Err(e) => {
                return Err(ClientError::Protocol(format!(
                    "bad frame length from server: {e}"
                )));
            }
        };
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body)?;
        let payload = body.split_off(1);
        Ok((body[0], payload))
    }

    fn read_response(&mut self) -> ClientResult<String> {
        if self.version.binary_frames() {
            // One frame is one response; the length prefix replaces the
            // blank-line terminator.
            let (opcode, payload) = self.read_frame()?;
            if opcode != codec::OP_TEXT_RESP {
                return Err(ClientError::Protocol(format!(
                    "unexpected frame opcode {opcode:#04x} (wanted a text response)"
                )));
            }
            return String::from_utf8(payload).map_err(|_| {
                ClientError::Protocol("text response frame is not UTF-8".into())
            });
        }
        let mut out = String::new();
        loop {
            let mut buf = String::new();
            let n = self.reader.read_line(&mut buf)?;
            if n == 0 {
                return Err(ClientError::Protocol("server closed the connection".into()));
            }
            if buf == "\n" {
                break;
            }
            out.push_str(&buf);
        }
        Ok(out.trim_end_matches('\n').to_string())
    }

    /// One typed round trip. `ERR` responses come back as
    /// [`ClientError::Api`].
    fn roundtrip(&mut self, req: &Request) -> ClientResult<Response> {
        let line = codec::render_request(req, self.version);
        self.send_line(&line)?;
        let raw = self.read_response()?;
        // A HELLO response is rendered in the *negotiated* version.
        let parse_version = match req {
            Request::Hello(v) => *v,
            _ => self.version,
        };
        match codec::parse_response(&raw, parse_version) {
            Ok(Response::Error(e)) => Err(ClientError::Api(e)),
            Ok(resp) => Ok(resp),
            Err(e) => Err(ClientError::Protocol(format!(
                "unparseable response {raw:?}: {e}"
            ))),
        }
    }

    /// Pipeline several requests over this connection: write every request
    /// line back-to-back, then read the responses, which the server
    /// guarantees arrive **in request order**. One round-trip's latency is
    /// paid once for the whole batch instead of once per request — the
    /// launcher-loop pattern ("submit, submit, …, stats") without N × RTT.
    ///
    /// Unlike the single-request helpers, `ERR` responses come back as
    /// [`Response::Error`] variants in the result vector (a failed request
    /// must not hide the responses behind it); transport failures are still
    /// `Err`. `HELLO` cannot be pipelined — it changes the wire version
    /// mid-stream, making the remaining responses unparseable.
    pub fn pipeline(&mut self, reqs: &[Request]) -> ClientResult<Vec<Response>> {
        if reqs.iter().any(|r| matches!(r, Request::Hello(_))) {
            return Err(ClientError::Protocol(
                "HELLO cannot be pipelined (it renegotiates the wire version)".into(),
            ));
        }
        for r in reqs {
            if let Request::MSubmit(m) = r {
                if let Some((i, tag)) = m.first_unframeable_tag() {
                    return Err(ClientError::Protocol(format!(
                        "manifest entry {i} has a tag that cannot be framed on the wire: {tag:?}"
                    )));
                }
            }
        }
        let mut batch = Vec::new();
        for req in reqs {
            let line = codec::render_request(req, self.version);
            if self.version.binary_frames() {
                batch.extend_from_slice(&codec::v3_frame(codec::OP_TEXT_REQ, line.as_bytes()));
            } else {
                batch.extend_from_slice(line.as_bytes());
                batch.push(b'\n');
            }
        }
        self.writer.write_all(&batch)?;
        self.writer.flush()?;
        let mut out = Vec::with_capacity(reqs.len());
        for _ in reqs {
            let raw = self.read_response()?;
            match codec::parse_response(&raw, self.version) {
                Ok(resp) => out.push(resp),
                Err(e) => {
                    return Err(ClientError::Protocol(format!(
                        "unparseable response {raw:?}: {e}"
                    )))
                }
            }
        }
        Ok(out)
    }

    /// Negotiate the protocol version for this connection.
    pub fn hello(&mut self, version: ProtocolVersion) -> ClientResult<ProtocolVersion> {
        match self.roundtrip(&Request::Hello(version))? {
            Response::Hello(v) => {
                self.version = v;
                Ok(v)
            }
            other => Err(unexpected("HELLO", &other)),
        }
    }

    /// Liveness check.
    pub fn ping(&mut self) -> ClientResult<()> {
        match self.roundtrip(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(unexpected("PING", &other)),
        }
    }

    /// Submit a (possibly batched) spec; returns the assigned id range.
    pub fn submit(&mut self, spec: &SubmitSpec) -> ClientResult<SubmitAck> {
        match self.roundtrip(&Request::Submit(spec.clone()))? {
            Response::SubmitAck(ack) => Ok(ack),
            other => Err(unexpected("SUBMIT", &other)),
        }
    }

    /// Submit a heterogeneous manifest in one RPC; returns per-entry job-id
    /// ranges and typed per-entry rejects (partial accept — a reject does
    /// not fail the call). Requires a v2 session: the v1 grammar cannot
    /// express a manifest, and the daemon would answer `unsupported`.
    pub fn msubmit(&mut self, manifest: &Manifest) -> ClientResult<ManifestAck> {
        if !self.version.is_v2() {
            return Err(ClientError::Protocol(
                "MSUBMIT requires protocol v2 (connect with Client::connect_v2)".into(),
            ));
        }
        if self.version.binary_frames() {
            return self.msubmit_frame(manifest);
        }
        // A tag with whitespace/`;`/newline would corrupt the single-line
        // record framing (a newline would even inject a second request):
        // refuse before any byte goes out.
        if let Some((i, tag)) = manifest.first_unframeable_tag() {
            return Err(ClientError::Protocol(format!(
                "manifest entry {i} has a tag that cannot be framed on the wire: {tag:?}"
            )));
        }
        match self.roundtrip(&Request::MSubmit(manifest.clone()))? {
            Response::ManifestAck(ack) => Ok(ack),
            other => Err(unexpected("MSUBMIT", &other)),
        }
    }

    /// Binary v3 `MSUBMIT`: the manifest goes out as one varint-packed
    /// frame and the ack comes back packed ([`codec::parse_manifest_ack_v3`])
    /// or as a framed typed error. Tag framing restrictions do not apply —
    /// binary records are length-delimited, so any tag the manifest
    /// validator accepts survives the wire unescaped.
    fn msubmit_frame(&mut self, manifest: &Manifest) -> ClientResult<ManifestAck> {
        self.send_frame(codec::OP_MSUBMIT, &codec::render_msubmit_v3(manifest))?;
        let (opcode, payload) = self.read_frame()?;
        match opcode {
            codec::OP_MANIFEST_ACK => codec::parse_manifest_ack_v3(&payload)
                .map_err(|e| ClientError::Protocol(format!("unparseable manifest ack: {e}"))),
            codec::OP_TEXT_RESP => {
                let raw = String::from_utf8_lossy(&payload).into_owned();
                match codec::parse_response(&raw, ProtocolVersion::V3) {
                    Ok(Response::Error(e)) => Err(ClientError::Api(e)),
                    Ok(resp) => Err(unexpected("MSUBMIT", &resp)),
                    Err(e) => Err(ClientError::Protocol(format!(
                        "unparseable response {raw:?}: {e}"
                    ))),
                }
            }
            other => Err(ClientError::Protocol(format!(
                "unexpected frame opcode {other:#04x}"
            ))),
        }
    }

    /// Submit a manifest as a chunked v2.1 stream: `entries=<total>
    /// part=<i>/<k>` continuation records of at most `chunk_size` entries
    /// each, lifting the single-line entry cap. Intermediate parts are
    /// acknowledged with `chunk_ack`; the final part admits the assembled
    /// manifest atomically and returns the normal [`ManifestAck`]. Any
    /// typed error mid-stream discards the server-side partial manifest —
    /// the stream is never resumable, re-send from part 1. Requires a
    /// v2.1 session ([`Client::connect_v21`]).
    pub fn msubmit_chunked(
        &mut self,
        manifest: &Manifest,
        chunk_size: usize,
    ) -> ClientResult<ManifestAck> {
        if !self.version.chunked_msubmit() {
            return Err(ClientError::Protocol(
                "chunked MSUBMIT requires protocol v2.1 (connect with Client::connect_v21)".into(),
            ));
        }
        if let Some((i, tag)) = manifest.first_unframeable_tag() {
            return Err(ClientError::Protocol(format!(
                "manifest entry {i} has a tag that cannot be framed on the wire: {tag:?}"
            )));
        }
        let total = manifest.entries.len();
        if total == 0 {
            // Nothing to chunk — the single-line form already expresses an
            // empty manifest.
            return self.msubmit(manifest);
        }
        if total > MAX_CHUNKED_MANIFEST_ENTRIES {
            return Err(ClientError::Protocol(format!(
                "manifest has {total} entries (chunked cap {MAX_CHUNKED_MANIFEST_ENTRIES})"
            )));
        }
        let chunk_size = chunk_size.max(1);
        let parts = (total + chunk_size - 1) / chunk_size;
        if parts > MAX_CHUNK_PARTS as usize {
            return Err(ClientError::Protocol(format!(
                "{total} entries at {chunk_size} per part is {parts} parts (cap {MAX_CHUNK_PARTS}) \
                 — raise chunk_size"
            )));
        }
        for (i, slice) in manifest.entries.chunks(chunk_size).enumerate() {
            let part = (i + 1) as u32;
            let chunk = ManifestChunk {
                entries: total as u32,
                part,
                parts: parts as u32,
                records: slice.to_vec(),
            };
            match self.roundtrip(&Request::MSubmitChunk(chunk))? {
                Response::ManifestAck(ack) if part as usize == parts => return Ok(ack),
                Response::ChunkAck { part: echoed, .. }
                    if (part as usize) < parts && echoed == part => {}
                other => return Err(unexpected("MSUBMIT", &other)),
            }
        }
        unreachable!("the final part returns its ManifestAck")
    }

    /// List jobs matching `filter`.
    pub fn squeue(&mut self, filter: &SqueueFilter) -> ClientResult<Vec<JobSummary>> {
        match self.roundtrip(&Request::Squeue(filter.clone()))? {
            Response::Jobs(rows) => Ok(rows),
            other => Err(unexpected("SQUEUE", &other)),
        }
    }

    /// Detail for one job.
    pub fn job(&mut self, id: u64) -> ClientResult<JobDetail> {
        match self.roundtrip(&Request::Sjob(id))? {
            Response::Job(d) => Ok(d),
            other => Err(unexpected("SJOB", &other)),
        }
    }

    /// Cancel a job; `Err(ClientError::Api)` with `NotFound` when unknown.
    pub fn cancel(&mut self, id: u64) -> ClientResult<u64> {
        match self.roundtrip(&Request::Scancel(id))? {
            Response::Cancelled(id) => Ok(id),
            other => Err(unexpected("SCANCEL", &other)),
        }
    }

    /// Block until `jobs` have all dispatched (or `timeout_secs` of wall
    /// time elapse) and return the burst's virtual scheduling latency — the
    /// paper's launch-latency measurement, end to end from a remote client.
    pub fn wait(&mut self, jobs: &[u64], timeout_secs: f64) -> ClientResult<WaitResult> {
        // An empty set is settled by definition, and the v1 grammar cannot
        // even express it — short-circuit without a round trip.
        if jobs.is_empty() {
            return Ok(WaitResult {
                requested: 0,
                dispatched: 0,
                timed_out: false,
                latency_ns: 0,
            });
        }
        // The daemon blocks up to timeout_secs; give the socket headroom.
        let io_timeout = Duration::from_secs_f64(timeout_secs.max(0.0) + 30.0);
        self.writer.set_read_timeout(Some(io_timeout))?;
        let result = self.roundtrip(&Request::Wait {
            jobs: jobs.to_vec(),
            timeout_secs,
        });
        self.writer.set_read_timeout(Some(DEFAULT_IO_TIMEOUT))?;
        match result? {
            Response::Wait(w) => Ok(w),
            other => Err(unexpected("WAIT", &other)),
        }
    }

    /// Block until one manifest entry's jobs have all dispatched (or the
    /// timeout elapses) — `WAIT manifest=<id> entry=<k>` on the wire, so
    /// the client needs only the ack/resume metadata, not the job ids.
    /// Requires a v2 session.
    pub fn wait_entry(
        &mut self,
        manifest: u64,
        entry: u32,
        timeout_secs: f64,
    ) -> ClientResult<WaitResult> {
        if !self.version.is_v2() {
            return Err(ClientError::Protocol(
                "per-entry WAIT requires protocol v2 (connect with Client::connect_v2)".into(),
            ));
        }
        let io_timeout = Duration::from_secs_f64(timeout_secs.max(0.0) + 30.0);
        self.writer.set_read_timeout(Some(io_timeout))?;
        let result = self.roundtrip(&Request::WaitEntry {
            manifest,
            entry,
            timeout_secs,
        });
        self.writer.set_read_timeout(Some(DEFAULT_IO_TIMEOUT))?;
        match result? {
            Response::Wait(w) => Ok(w),
            other => Err(unexpected("WAIT", &other)),
        }
    }

    /// Re-attach to the latest manifest registered under `tag`: returns its
    /// per-entry settlement so the caller collects exactly the
    /// not-yet-settled entries ([`ResumeInfo::pending_entries`]). Requires
    /// a v2 session.
    pub fn resume_by_tag(&mut self, tag: &str) -> ClientResult<ResumeInfo> {
        self.resume(Request::Resume(ResumeTarget::Tag(tag.to_string())))
    }

    /// Re-attach to a specific manifest id (from a prior `MSUBMIT` ack).
    /// Requires a v2 session.
    pub fn resume_by_manifest(&mut self, manifest: u64) -> ClientResult<ResumeInfo> {
        self.resume(Request::Resume(ResumeTarget::Manifest(manifest)))
    }

    fn resume(&mut self, req: Request) -> ClientResult<ResumeInfo> {
        if !self.version.is_v2() {
            return Err(ClientError::Protocol(
                "RESUME requires protocol v2 (connect with Client::connect_v2)".into(),
            ));
        }
        match self.roundtrip(&req)? {
            Response::Resume(info) => Ok(info),
            other => Err(unexpected("RESUME", &other)),
        }
    }

    /// Daemon + scheduler counters.
    pub fn stats(&mut self) -> ClientResult<StatsSnapshot> {
        match self.roundtrip(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(unexpected("STATS", &other)),
        }
    }

    /// Cluster utilization snapshot.
    pub fn util(&mut self) -> ClientResult<UtilSnapshot> {
        match self.roundtrip(&Request::Util)? {
            Response::Util(u) => Ok(u),
            other => Err(unexpected("UTIL", &other)),
        }
    }

    /// Daemon overload/health state (`HEALTH`): current state, pressure
    /// counters, and how long the state has held.
    pub fn health(&mut self) -> ClientResult<HealthReport> {
        match self.roundtrip(&Request::Health)? {
            Response::Health(h) => Ok(h),
            other => Err(unexpected("HEALTH", &other)),
        }
    }

    /// Ask the daemon to stop.
    pub fn shutdown(&mut self) -> ClientResult<()> {
        match self.roundtrip(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => Err(unexpected("SHUTDOWN", &other)),
        }
    }
}

fn unexpected(cmd: &str, resp: &Response) -> ClientError {
    ClientError::Protocol(format!("unexpected response to {cmd}: {resp:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::api::ErrorCode;

    #[test]
    fn retry_delays_are_exponential_bounded_and_jittered() {
        let p = RetryPolicy::default();
        let mut rng = Xoshiro256::new(7);
        let mut prev_cap = Duration::ZERO;
        for attempt in 0..12 {
            let cap = p
                .base_delay
                .saturating_mul(1u32 << attempt.min(16))
                .min(p.max_delay);
            let d = p.delay_after(attempt, &mut rng);
            assert!(d <= cap, "attempt {attempt}: {d:?} > {cap:?}");
            assert!(d >= cap.mul_f64(0.5), "attempt {attempt}: {d:?} < half-cap");
            assert!(cap >= prev_cap, "caps must be monotone");
            prev_cap = cap;
        }
        // The cap saturates at max_delay.
        assert_eq!(prev_cap, p.max_delay);
    }

    #[test]
    fn retry_runs_until_success_and_gives_up_after_attempts() {
        let quick = RetryPolicy {
            attempts: 4,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(2),
            seed: 1,
        };
        // Succeeds on the third try.
        let mut calls = 0;
        let out = quick.run(|| {
            calls += 1;
            if calls < 3 {
                Err(ClientError::Io(std::io::Error::new(std::io::ErrorKind::Other, "down")))
            } else {
                Ok(calls)
            }
        });
        assert_eq!(out.unwrap(), 3);
        // Exhausts attempts and surfaces the transport error.
        let mut calls = 0;
        let out: ClientResult<()> = quick.run(|| {
            calls += 1;
            Err(ClientError::Io(std::io::Error::new(std::io::ErrorKind::Other, "still down")))
        });
        assert_eq!(calls, 4);
        assert!(matches!(out, Err(ClientError::Io(_))));
    }

    #[test]
    fn retry_honors_overloaded_shed_and_its_retry_hint() {
        let quick = RetryPolicy {
            attempts: 4,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(2),
            seed: 1,
        };
        // A shed daemon answers `overloaded` with a retry hint; the
        // policy sleeps the hint and tries again until admitted.
        let mut calls = 0;
        let started = std::time::Instant::now();
        let out = quick.run(|| {
            calls += 1;
            if calls < 3 {
                Err(ClientError::Api(ApiError::overloaded(
                    "admission budget exhausted",
                    5,
                )))
            } else {
                Ok(calls)
            }
        });
        assert_eq!(out.unwrap(), 3);
        // Two refusals, each hinting 5ms: the elapsed time shows the
        // hint was honored rather than the (sub-hint) jittered backoff.
        assert!(started.elapsed() >= Duration::from_millis(10));
        // Exhausting attempts surfaces the typed shed, not a panic.
        let mut calls = 0;
        let out: ClientResult<()> = quick.run(|| {
            calls += 1;
            Err(ClientError::Api(ApiError::overloaded("still shedding", 1)))
        });
        assert_eq!(calls, 4);
        match out {
            Err(ClientError::Api(e)) => {
                assert_eq!(e.code, ErrorCode::Overloaded);
                assert_eq!(e.retry_after_ms, Some(1));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn retry_does_not_mask_typed_api_errors() {
        // An API error means the daemon answered: retrying is wrong.
        let mut calls = 0;
        let out: ClientResult<()> = RetryPolicy::default().run(|| {
            calls += 1;
            Err(ClientError::Api(ApiError::new(
                ErrorCode::NotFound,
                "no manifest tagged x",
            )))
        });
        assert_eq!(calls, 1);
        match out {
            Err(ClientError::Api(e)) => assert_eq!(e.code, ErrorCode::NotFound),
            other => panic!("{other:?}"),
        }
    }
}
