//! The write-ahead journal behind the daemon's durability guarantee.
//!
//! Every admitted submission/manifest/cancel is appended here — and, per
//! the configured [`FsyncPolicy`], fsync'd — *before* the snapshot publish
//! that makes the mutation externally visible. An acknowledged RPC is
//! therefore recoverable: kill the daemon at any point and
//! `Daemon::recover` rebuilds the scheduler by replaying the newest
//! checkpoint plus the journal tail (see [`super::recovery`]).
//!
//! ## On-disk format
//!
//! A journal is a directory of segment files `seg-<seq>.wal`. Each segment
//! starts with the 8-byte magic [`JOURNAL_MAGIC`], then a sequence of
//! framed records:
//!
//! ```text
//! [u32 payload_len (LE)] [u32 crc32(payload) (LE)] [payload bytes]
//! ```
//!
//! The first record of every segment is a [`JournalRecord::Checkpoint`]
//! (a genesis empty-state checkpoint for a fresh journal), so any single
//! segment is sufficient to rebuild. Checkpointing **rotates**: the new
//! checkpoint is written to a fresh segment, fsync'd, and only then are the
//! older segments deleted — that is how the journal stays bounded
//! (checkpoint-truncation). Segment creation and checkpoints are always
//! synced regardless of policy; [`FsyncPolicy`] governs per-append syncs
//! only.
//!
//! Recovery scans segments newest-first and picks the first one whose
//! leading checkpoint is intact (a crash mid-checkpoint leaves a torn
//! segment that is discarded in favor of its predecessor). A torn final
//! record — a crash mid-append — is truncated, never fatal.
//!
//! ## Sharded layout
//!
//! A single-shard daemon journals into a flat directory of `seg-*.wal`
//! files (the layout above, byte-for-byte the original format). A sharded
//! daemon (`shard_count > 1`) instead keeps **one journal per scheduler
//! shard** under `shard-<i>/seg-*.wal`, appended under that shard's mutex,
//! plus a small **allocator log** `alloc.log` ([`AllocLog`]) of id-range
//! lease records: every sharded admission first appends the lease
//! (`lease seq → [first, first+count)`) there, then one
//! [`JournalRecord::ShardAdmit`] *part* per touched shard. Each part
//! redundantly carries the whole lease header (seq, id range, the touched
//! shard set), so recovery can reconcile a cross-shard manifest from any
//! shard's journal: a lease is replayed only when every touched shard
//! either has the part in its tail or checkpointed past the lease
//! (`applied_lease`); anything else is a torn, never-acked admission and
//! is dropped whole. The two layouts never mix in one directory.
//!
//! ## Group commit
//!
//! Under `fsync = always`, concurrent admissions would pay one fsync per
//! RPC. [`Journal::append_deferred`] + [`Journal::group_sync`] let the
//! daemon batch them: writers append (no sync) under the journal lock,
//! release it, and then one leader syncs everything appended so far while
//! the rest park (see the daemon's parked-writer protocol). The ack still
//! waits for the fsync covering its record, so the no-acked-loss contract
//! holds.
//!
//! ## Crash injection
//!
//! [`FaultPlan`] lets the test harness arm countdown faults at the
//! interesting points (after append / before fsync, after fsync / before
//! publish, mid-checkpoint, mid-allocator-log-append). A fault poisons the
//! journal and, for the pre-fsync point, actively truncates the file back
//! to the last durable byte — faithfully simulating the page-cache loss of
//! a power cut without killing the test process. [`FaultPlan::arm_after`]
//! skips the first `n` hits, which is how a test crashes *between* shard
//! A's append and shard B's append of one cross-shard manifest.

use super::manifest::{ManifestEntry, ManifestSpan, RegisteredManifest};
use super::snapshot::JobView;
use crate::job::{JobSpec, JobState, JobType, QosClass, UserId};
use crate::sched::LogKind;
use crate::sim::SimTime;
use std::fmt;
use std::fs::{self, File, OpenOptions};
use std::io::{self, Seek, SeekFrom, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

/// Leading bytes of every segment file.
pub const JOURNAL_MAGIC: &[u8; 8] = b"SPOTWAL1";

/// Sanity cap on one record's payload (a maximal manifest checkpoint is
/// a few MB; anything near this is framing corruption, not data).
const MAX_RECORD_LEN: usize = 256 << 20;

// ---------------------------------------------------------------- config

/// When appends hit the platter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync after every append: an acked RPC survives power loss.
    Always,
    /// fsync every `appends` appends: bounded loss window, near-`Never`
    /// throughput. An acked RPC survives daemon crash (the bytes are in
    /// the page cache) but the tail since the last sync can be lost to
    /// power failure.
    Interval {
        /// Appends between syncs (≥ 1; 1 behaves like `Always`).
        appends: u32,
    },
    /// Never fsync appends: acked work survives a daemon crash only.
    Never,
}

impl Default for FsyncPolicy {
    fn default() -> Self {
        FsyncPolicy::Interval { appends: 64 }
    }
}

impl FsyncPolicy {
    /// Parse the CLI form: `always`, `never`, `interval` (default stride),
    /// or `interval:<n>`.
    pub fn parse(s: &str) -> Option<FsyncPolicy> {
        match s {
            "always" => Some(FsyncPolicy::Always),
            "never" => Some(FsyncPolicy::Never),
            "interval" => Some(FsyncPolicy::default()),
            _ => {
                let n: u32 = s.strip_prefix("interval:")?.parse().ok()?;
                (n >= 1).then_some(FsyncPolicy::Interval { appends: n })
            }
        }
    }

    /// Report label.
    pub fn label(self) -> &'static str {
        match self {
            FsyncPolicy::Always => "always",
            FsyncPolicy::Interval { .. } => "interval",
            FsyncPolicy::Never => "never",
        }
    }
}

/// Where the crash-injection harness can stop the world.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// After the record is written, before it is fsync'd (and therefore
    /// before the client is acked): the record is *lost* — recovery must
    /// not resurrect it, and the client never saw an ack for it.
    AfterAppend,
    /// After the record is durable, before the publish/ack: the record
    /// *survives* — recovery resurrects work the client was never acked
    /// for (the documented at-least-once edge; resume-by-tag is the
    /// idempotency story).
    AfterFsync,
    /// Mid-checkpoint rotation: the new segment is torn; recovery must
    /// fall back to the previous segment's checkpoint + tail.
    MidCheckpoint,
    /// Mid-append on the allocator log (sharded mode): the lease record is
    /// torn — recovery must truncate it and drop any shard-journal part
    /// that was never appended under it.
    AllocAppend,
}

/// Countdown fault arms shared between a test and a running daemon's
/// journal. `Clone` shares the arms (the plan travels inside
/// `DaemonConfig`, which must stay `Clone`). Each point holds a countdown:
/// `-1` disarmed, `0` fires on the next hit, `n > 0` lets `n` hits pass
/// first.
///
/// Arms may additionally be *targeted* at one scheduler shard's journal
/// with [`FaultPlan::arm_for_shard`]: the shared `target` cell names the
/// shard index the arm applies to, and each shard journal's plan clone
/// carries its own (non-shared) `scope` stamped by
/// [`DurabilityConfig::for_shard`]. A hit from any other shard passes
/// through without even decrementing the countdown — which is how a test
/// crashes shard 1's append of a cross-shard admission regardless of which
/// shard the scheduler happens to append first.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    after_append: Arc<AtomicI64>,
    after_fsync: Arc<AtomicI64>,
    mid_checkpoint: Arc<AtomicI64>,
    alloc_append: Arc<AtomicI64>,
    /// Shard index the current arms are confined to; `-1` = any hitter.
    /// Shared, so one `arm_for_shard` call from the test side is seen by
    /// every shard journal's clone.
    target: Arc<AtomicI64>,
    /// Which shard's journal *this clone* belongs to. Deliberately not
    /// behind an `Arc`: `for_shard` stamps the clone it hands to shard
    /// `idx`, while the root plan (and the allocator log's) stay `None`.
    scope: Option<usize>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        // A derived Default would zero the countdowns — i.e. every fault
        // armed to fire on first hit. Disarmed is -1.
        Self::new()
    }
}

impl FaultPlan {
    /// A plan with every fault disarmed.
    pub fn new() -> Self {
        Self {
            after_append: Arc::new(AtomicI64::new(-1)),
            after_fsync: Arc::new(AtomicI64::new(-1)),
            mid_checkpoint: Arc::new(AtomicI64::new(-1)),
            alloc_append: Arc::new(AtomicI64::new(-1)),
            target: Arc::new(AtomicI64::new(-1)),
            scope: None,
        }
    }

    fn arm_of(&self, point: FaultPoint) -> &Arc<AtomicI64> {
        match point {
            FaultPoint::AfterAppend => &self.after_append,
            FaultPoint::AfterFsync => &self.after_fsync,
            FaultPoint::MidCheckpoint => &self.mid_checkpoint,
            FaultPoint::AllocAppend => &self.alloc_append,
        }
    }

    /// Arm a fault: the next time the journal reaches `point` it fails
    /// (once — firing disarms, so recovery can reuse the same config).
    /// Clears any shard targeting: the arm applies to whichever journal
    /// hits the point first.
    pub fn arm(&self, point: FaultPoint) {
        self.target.store(-1, Ordering::SeqCst);
        self.arm_of(point).store(0, Ordering::SeqCst);
    }

    /// Arm a fault that lets the first `skip` hits pass and fires on hit
    /// `skip + 1`. `arm_after(p, 0)` is `arm(p)`. Untargeted, like `arm`.
    pub fn arm_after(&self, point: FaultPoint, skip: u32) {
        self.target.store(-1, Ordering::SeqCst);
        self.arm_of(point).store(skip as i64, Ordering::SeqCst);
    }

    /// Arm a fault confined to scheduler shard `shard`'s journal: hits
    /// from every other shard pass through without consuming the
    /// countdown, and shard `shard`'s next hit of `point` fires. This
    /// pins down *which* WAL of a cross-shard operation crashes, where
    /// `arm_after(point, n)` could only count global hits and so depended
    /// on shard append order.
    pub fn arm_for_shard(&self, shard: usize, point: FaultPoint) {
        self.target.store(shard as i64, Ordering::SeqCst);
        self.arm_of(point).store(0, Ordering::SeqCst);
    }

    /// Is the fault currently armed (counting down or about to fire)?
    pub fn armed(&self, point: FaultPoint) -> bool {
        self.arm_of(point).load(Ordering::SeqCst) >= 0
    }

    /// Count down one hit; `true` exactly when the countdown reaches its
    /// firing point (which disarms it). A hit from outside the targeted
    /// shard (when one is set) is invisible: no fire, no decrement.
    fn take(&self, point: FaultPoint) -> bool {
        let target = self.target.load(Ordering::SeqCst);
        if target >= 0 && self.scope != Some(target as usize) {
            return false;
        }
        let fired = self
            .arm_of(point)
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| match v {
                -1 => None,        // disarmed
                0 => Some(-1),     // fire and disarm
                n => Some(n - 1),  // let this hit pass
            })
            .map(|prev| prev == 0)
            .unwrap_or(false);
        if fired {
            // Firing disarms the targeting too, so a later untargeted
            // `arm` on the same shared plan behaves as documented.
            self.target.store(-1, Ordering::SeqCst);
        }
        fired
    }
}

/// The `durability` section of `DaemonConfig`.
#[derive(Debug, Clone)]
pub struct DurabilityConfig {
    /// Journal directory (created if absent).
    pub dir: PathBuf,
    /// Per-append sync policy.
    pub fsync: FsyncPolicy,
    /// Checkpoint (and truncate) after this many appended records.
    pub checkpoint_every: u64,
    /// Also checkpoint when the live segment exceeds this size.
    pub max_segment_bytes: u64,
    /// Batch concurrent `fsync = always` admissions into one sync (the
    /// parked-writer group commit; no effect under other policies, which
    /// already amortize). On: an ack still waits for the fsync covering
    /// its record, but a failed group sync leaves the admission
    /// applied-but-unacked (the same class as `SCANCEL`'s documented
    /// mutate-then-append divergence). Off restores strict
    /// append-sync-then-mutate per RPC.
    pub group_commit: bool,
    /// Crash-injection arms (disarmed in production).
    pub faults: FaultPlan,
}

impl DurabilityConfig {
    /// Durability at `dir` with default policy (interval fsync, 4096
    /// records or 64 MB per segment, group commit on).
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            fsync: FsyncPolicy::default(),
            checkpoint_every: 4096,
            max_segment_bytes: 64 << 20,
            group_commit: true,
            faults: FaultPlan::new(),
        }
    }

    /// Builder: fsync policy.
    pub fn with_fsync(mut self, fsync: FsyncPolicy) -> Self {
        self.fsync = fsync;
        self
    }

    /// Builder: checkpoint stride in records.
    pub fn with_checkpoint_every(mut self, every: u64) -> Self {
        self.checkpoint_every = every.max(1);
        self
    }

    /// Builder: group commit on/off.
    pub fn with_group_commit(mut self, on: bool) -> Self {
        self.group_commit = on;
        self
    }

    /// The same config re-rooted at a scheduler shard's journal directory
    /// (`<dir>/shard-<idx>`); the fault plan's arms stay shared, so one
    /// armed countdown spans every shard's journal — but the clone is
    /// stamped with the shard index, which is what lets
    /// [`FaultPlan::arm_for_shard`] confine a fault to this shard's WAL.
    pub fn for_shard(&self, idx: usize) -> DurabilityConfig {
        let mut cfg = self.clone();
        cfg.dir = shard_journal_dir(&self.dir, idx);
        cfg.faults.scope = Some(idx);
        cfg
    }
}

// ---------------------------------------------------------------- errors

/// Why a journal operation failed.
#[derive(Debug)]
pub enum JournalError {
    /// Underlying filesystem error.
    Io(io::Error),
    /// Framing/decoding corruption beyond what torn-tail truncation heals.
    Corrupt(String),
    /// `create` on a directory that already holds segments (recover it).
    NotEmpty(PathBuf),
    /// `recover` on a directory with no segments (create instead).
    Empty(PathBuf),
    /// A previous error (or injected fault) poisoned this journal handle.
    Poisoned,
    /// An injected crash fault fired.
    Fault(&'static str),
}

impl fmt::Display for JournalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JournalError::Io(e) => write!(f, "journal I/O error: {e}"),
            JournalError::Corrupt(what) => write!(f, "journal corrupt: {what}"),
            JournalError::NotEmpty(p) => {
                write!(f, "journal directory {} already has segments", p.display())
            }
            JournalError::Empty(p) => {
                write!(f, "journal directory {} has no segments", p.display())
            }
            JournalError::Poisoned => write!(f, "journal poisoned by a previous error"),
            JournalError::Fault(point) => write!(f, "injected crash fault: {point}"),
        }
    }
}

impl std::error::Error for JournalError {}

impl From<io::Error> for JournalError {
    fn from(e: io::Error) -> Self {
        JournalError::Io(e)
    }
}

fn corrupt(what: impl Into<String>) -> JournalError {
    JournalError::Corrupt(what.into())
}

// ----------------------------------------------------------------- crc32

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3, the zlib polynomial).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc = (crc >> 8) ^ CRC_TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

// --------------------------------------------------------------- records

/// One admitted-entry record inside an [`JournalRecord::Admit`]: the
/// manifest entry (or the synthesized single entry of a legacy `SUBMIT`)
/// plus its index in the original manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmitEntry {
    /// Index into the original manifest (0 for `SUBMIT`).
    pub index: u32,
    /// The admitted entry. Id spans are *not* stored: replay re-admits the
    /// entries in order and the scheduler's deterministic id assignment
    /// reproduces them (verified against `first_id`/`total_jobs`).
    pub entry: ManifestEntry,
}

/// One live job inside a checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointJob {
    /// Job id.
    pub id: u64,
    /// State at capture (recovery re-queues the job as Pending; the
    /// pre-crash state feeds the `RecoveryReport` breakdown).
    pub state: JobState,
    /// Original submission time.
    pub submit_time: SimTime,
    /// Preempt+requeue count at capture.
    pub requeue_count: u32,
    /// The immutable spec.
    pub spec: JobSpec,
    /// The job's event-log entries at capture, oldest first (so SJOB on a
    /// recovered job still reports its pre-crash recognized/dispatch
    /// times).
    pub log: Vec<(SimTime, LogKind)>,
}

/// A full scheduler-state checkpoint: everything recovery needs that the
/// tail records cannot re-derive.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointState {
    /// Virtual time at capture.
    pub vtime: SimTime,
    /// The scheduler's next job id (covers retired ids that no live job
    /// or tail record would otherwise reproduce).
    pub next_id: u64,
    /// The manifest registry's next id.
    pub next_manifest_id: u64,
    /// Live (non-retired) jobs.
    pub jobs: Vec<CheckpointJob>,
    /// The daemon's retired-history views, insertion (retirement) order —
    /// so a recovered daemon answers `SJOB`/`WAIT` on retired pre-crash
    /// ids with the same history semantics as the live daemon.
    pub history: Vec<JobView>,
    /// The manifest registry (resume/wait-entry lookups).
    pub manifests: Vec<RegisteredManifest>,
    /// Daemon-global capture sequence (sharded mode; 0 unsharded).
    /// Allocated under the registry lock at capture, so across shards the
    /// max-`global_seq` checkpoint holds the freshest registry + history —
    /// recovery restores those global tables from it alone.
    pub global_seq: u64,
    /// Highest allocator-log lease whose part this shard had applied when
    /// the checkpoint was captured (sharded mode; 0 unsharded). Monotone
    /// per shard: lease seqs are allocated inside the shard-lock critical
    /// sections. Recovery's torn-lease reconciliation counts a shard as
    /// covering lease `L` when its part is in the tail *or*
    /// `applied_lease >= L` (the part was absorbed by this checkpoint).
    pub applied_lease: u64,
}

impl CheckpointState {
    /// The empty state a fresh journal starts from.
    pub fn genesis() -> Self {
        Self {
            vtime: SimTime::ZERO,
            next_id: 1,
            next_manifest_id: 1,
            jobs: Vec::new(),
            history: Vec::new(),
            manifests: Vec::new(),
            global_seq: 0,
            applied_lease: 0,
        }
    }
}

/// One journal record.
#[derive(Debug, Clone, PartialEq)]
pub enum JournalRecord {
    /// An admitted submission (legacy `SUBMIT` or manifest `MSUBMIT`).
    Admit {
        /// Virtual admission time (replay advances the scheduler here
        /// before re-admitting).
        vtime: SimTime,
        /// First job id the scheduler assigned.
        first_id: u64,
        /// Total jobs admitted (replay cross-check).
        total_jobs: u64,
        /// Registered manifest id, if any (`None` for `SUBMIT`).
        manifest: Option<u64>,
        /// Accepted entries, admission order.
        entries: Vec<AdmitEntry>,
    },
    /// An acknowledged `SCANCEL`.
    Cancel {
        /// Virtual cancel time.
        vtime: SimTime,
        /// The cancelled job id.
        id: u64,
    },
    /// A scheduler-state checkpoint (always the first record of a
    /// segment).
    Checkpoint(CheckpointState),
    /// One shard's part of a sharded admission. The lease header (seq, id
    /// range, touched-shard set) is carried redundantly in *every* part,
    /// so recovery can reconcile a cross-shard manifest from whichever
    /// journals survive: the lease replays only when every shard in
    /// `shards` is covered (part in tail, or checkpointed past the lease).
    ShardAdmit {
        /// Virtual admission time on this shard.
        vtime: SimTime,
        /// The allocator-log lease this admission's ids came from.
        lease: u64,
        /// First id of the whole lease (all shards).
        lease_first: u64,
        /// Total jobs of the whole lease (all shards).
        lease_total: u64,
        /// Every shard index the lease touched, ascending.
        shards: Vec<u32>,
        /// Registered manifest id, if any.
        manifest: Option<u64>,
        /// This shard's consecutive-entry runs. Each run carries its own
        /// explicit first id: one lease's runs on one shard need not be
        /// contiguous (other shards' runs interleave in manifest order),
        /// and explicit ids keep replay exact even when another lease in
        /// between was dropped as torn.
        runs: Vec<AdmitRun>,
    },
}

/// One consecutive-entry run inside a [`JournalRecord::ShardAdmit`].
#[derive(Debug, Clone, PartialEq)]
pub struct AdmitRun {
    /// First job id of the run (replay `force_next_id`s to it).
    pub first_id: u64,
    /// The run's admitted entries, manifest order.
    pub entries: Vec<AdmitEntry>,
}

impl AdmitRun {
    /// Jobs this run materializes.
    pub fn jobs(&self) -> u64 {
        self.entries.iter().map(|a| a.entry.jobs()).sum()
    }
}

// ------------------------------------------------- binary encode helpers

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Self {
        Self { buf: Vec::with_capacity(64) }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn time(&mut self, t: SimTime) {
        self.u64(t.as_nanos());
    }
    fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u64(x);
            }
            None => self.u8(0),
        }
    }
    fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn opt_str(&mut self, s: Option<&str>) {
        match s {
            Some(s) => {
                self.u8(1);
                self.str(s);
            }
            None => self.u8(0),
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }
    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8], JournalError> {
        if self.buf.len() - self.pos < n {
            return Err(corrupt(format!("truncated {what}")));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }
    fn u8(&mut self, what: &str) -> Result<u8, JournalError> {
        Ok(self.bytes(1, what)?[0])
    }
    fn u32(&mut self, what: &str) -> Result<u32, JournalError> {
        Ok(u32::from_le_bytes(self.bytes(4, what)?.try_into().unwrap()))
    }
    fn u64(&mut self, what: &str) -> Result<u64, JournalError> {
        Ok(u64::from_le_bytes(self.bytes(8, what)?.try_into().unwrap()))
    }
    fn f64(&mut self, what: &str) -> Result<f64, JournalError> {
        Ok(f64::from_bits(self.u64(what)?))
    }
    fn time(&mut self, what: &str) -> Result<SimTime, JournalError> {
        Ok(SimTime(self.u64(what)?))
    }
    fn opt_u64(&mut self, what: &str) -> Result<Option<u64>, JournalError> {
        match self.u8(what)? {
            0 => Ok(None),
            1 => Ok(Some(self.u64(what)?)),
            t => Err(corrupt(format!("bad option tag {t} in {what}"))),
        }
    }
    fn opt_f64(&mut self, what: &str) -> Result<Option<f64>, JournalError> {
        match self.u8(what)? {
            0 => Ok(None),
            1 => Ok(Some(self.f64(what)?)),
            t => Err(corrupt(format!("bad option tag {t} in {what}"))),
        }
    }
    fn str(&mut self, what: &str) -> Result<String, JournalError> {
        let len = self.u32(what)? as usize;
        if len > MAX_RECORD_LEN {
            return Err(corrupt(format!("oversized string in {what}")));
        }
        let bytes = self.bytes(len, what)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| corrupt(format!("bad utf-8 in {what}")))
    }
    fn opt_str(&mut self, what: &str) -> Result<Option<String>, JournalError> {
        match self.u8(what)? {
            0 => Ok(None),
            1 => Ok(Some(self.str(what)?)),
            t => Err(corrupt(format!("bad option tag {t} in {what}"))),
        }
    }
    fn len(&mut self, what: &str) -> Result<usize, JournalError> {
        let n = self.u32(what)? as usize;
        // Each element costs at least one byte; a count beyond the buffer
        // is corruption and must not drive a giant allocation.
        if n > self.buf.len() - self.pos {
            return Err(corrupt(format!("oversized count in {what}")));
        }
        Ok(n)
    }
    fn finish(self, what: &str) -> Result<(), JournalError> {
        if self.pos != self.buf.len() {
            return Err(corrupt(format!("trailing bytes after {what}")));
        }
        Ok(())
    }
}

// stable one-byte codes for the persisted enums; never renumber, only append
fn qos_code(q: QosClass) -> u8 {
    match q {
        QosClass::Normal => 0,
        QosClass::Spot => 1,
    }
}
fn qos_from(c: u8) -> Result<QosClass, JournalError> {
    match c {
        0 => Ok(QosClass::Normal),
        1 => Ok(QosClass::Spot),
        _ => Err(corrupt(format!("bad qos code {c}"))),
    }
}
fn type_code(t: JobType) -> u8 {
    match t {
        JobType::Individual => 0,
        JobType::Array => 1,
        JobType::TripleMode => 2,
    }
}
fn type_from(c: u8) -> Result<JobType, JournalError> {
    match c {
        0 => Ok(JobType::Individual),
        1 => Ok(JobType::Array),
        2 => Ok(JobType::TripleMode),
        _ => Err(corrupt(format!("bad job-type code {c}"))),
    }
}
fn state_code(s: JobState) -> u8 {
    match s {
        JobState::Pending => 0,
        JobState::Running => 1,
        JobState::Completed => 2,
        JobState::Requeued => 3,
        JobState::Cancelled => 4,
        JobState::Suspended => 5,
    }
}
fn state_from(c: u8) -> Result<JobState, JournalError> {
    match c {
        0 => Ok(JobState::Pending),
        1 => Ok(JobState::Running),
        2 => Ok(JobState::Completed),
        3 => Ok(JobState::Requeued),
        4 => Ok(JobState::Cancelled),
        5 => Ok(JobState::Suspended),
        _ => Err(corrupt(format!("bad job-state code {c}"))),
    }
}

const TAG_ADMIT: u8 = 1;
const TAG_CANCEL: u8 = 2;
const TAG_CHECKPOINT: u8 = 3;
const TAG_SHARD_ADMIT: u8 = 4;

fn enc_manifest_entry(e: &mut Enc, m: &ManifestEntry) {
    e.u32(m.user);
    e.u8(qos_code(m.qos));
    e.u8(type_code(m.job_type));
    e.u32(m.tasks);
    e.u32(m.cores_per_task);
    e.f64(m.run_secs);
    e.u32(m.count);
    e.opt_str(m.tag.as_deref());
}

fn dec_manifest_entry(d: &mut Dec<'_>) -> Result<ManifestEntry, JournalError> {
    Ok(ManifestEntry {
        user: d.u32("entry.user")?,
        qos: qos_from(d.u8("entry.qos")?)?,
        job_type: type_from(d.u8("entry.type")?)?,
        tasks: d.u32("entry.tasks")?,
        cores_per_task: d.u32("entry.cores")?,
        run_secs: d.f64("entry.run_secs")?,
        count: d.u32("entry.count")?,
        tag: d.opt_str("entry.tag")?.map(Arc::from),
    })
}

fn enc_spec(e: &mut Enc, s: &JobSpec) {
    e.u32(s.user.0);
    e.u8(qos_code(s.qos));
    e.u8(type_code(s.job_type));
    e.u32(s.tasks);
    e.u32(s.cores_per_task);
    e.time(s.run_time);
    e.str(&s.tag);
}

fn dec_spec(d: &mut Dec<'_>) -> Result<JobSpec, JournalError> {
    Ok(JobSpec {
        user: UserId(d.u32("spec.user")?),
        qos: qos_from(d.u8("spec.qos")?)?,
        job_type: type_from(d.u8("spec.type")?)?,
        tasks: d.u32("spec.tasks")?,
        cores_per_task: d.u32("spec.cores")?,
        run_time: d.time("spec.run_time")?,
        tag: Arc::from(d.str("spec.tag")?),
    })
}

fn enc_view(e: &mut Enc, v: &JobView) {
    e.u64(v.id);
    e.u8(type_code(v.job_type));
    e.u32(v.tasks);
    e.u32(v.user);
    e.u8(qos_code(v.qos));
    e.u8(state_code(v.state));
    e.f64(v.submit_secs);
    e.f64(v.queue_secs);
    e.opt_f64(v.start_secs);
    e.opt_f64(v.end_secs);
    e.u32(v.requeues);
    e.opt_u64(v.recognized.map(SimTime::as_nanos));
    e.opt_u64(v.dispatched.map(SimTime::as_nanos));
    e.str(&v.tag);
    e.u64(v.revision);
}

fn dec_view(d: &mut Dec<'_>) -> Result<JobView, JournalError> {
    Ok(JobView {
        id: d.u64("view.id")?,
        job_type: type_from(d.u8("view.type")?)?,
        tasks: d.u32("view.tasks")?,
        user: d.u32("view.user")?,
        qos: qos_from(d.u8("view.qos")?)?,
        state: state_from(d.u8("view.state")?)?,
        submit_secs: d.f64("view.submit")?,
        queue_secs: d.f64("view.queue")?,
        start_secs: d.opt_f64("view.start")?,
        end_secs: d.opt_f64("view.end")?,
        requeues: d.u32("view.requeues")?,
        recognized: d.opt_u64("view.recognized")?.map(SimTime),
        dispatched: d.opt_u64("view.dispatched")?.map(SimTime),
        tag: Arc::from(d.str("view.tag")?),
        revision: d.u64("view.revision")?,
    })
}

impl JournalRecord {
    /// Serialize to the frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            JournalRecord::Admit {
                vtime,
                first_id,
                total_jobs,
                manifest,
                entries,
            } => {
                e.u8(TAG_ADMIT);
                e.time(*vtime);
                e.u64(*first_id);
                e.u64(*total_jobs);
                e.opt_u64(*manifest);
                e.u32(entries.len() as u32);
                for a in entries {
                    e.u32(a.index);
                    enc_manifest_entry(&mut e, &a.entry);
                }
            }
            JournalRecord::Cancel { vtime, id } => {
                e.u8(TAG_CANCEL);
                e.time(*vtime);
                e.u64(*id);
            }
            JournalRecord::Checkpoint(cp) => {
                e.u8(TAG_CHECKPOINT);
                e.time(cp.vtime);
                e.u64(cp.next_id);
                e.u64(cp.next_manifest_id);
                e.u32(cp.jobs.len() as u32);
                for j in &cp.jobs {
                    e.u64(j.id);
                    e.u8(state_code(j.state));
                    e.time(j.submit_time);
                    e.u32(j.requeue_count);
                    enc_spec(&mut e, &j.spec);
                    e.u32(j.log.len() as u32);
                    for &(t, kind) in &j.log {
                        e.time(t);
                        e.u8(kind.wire_code());
                    }
                }
                e.u32(cp.history.len() as u32);
                for v in &cp.history {
                    enc_view(&mut e, v);
                }
                e.u32(cp.manifests.len() as u32);
                for m in &cp.manifests {
                    e.u64(m.id);
                    e.u32(m.spans.len() as u32);
                    for s in &m.spans {
                        e.u32(s.index);
                        e.u64(s.first);
                        e.u64(s.count);
                        e.opt_str(s.tag.as_deref());
                    }
                }
                e.u64(cp.global_seq);
                e.u64(cp.applied_lease);
            }
            JournalRecord::ShardAdmit {
                vtime,
                lease,
                lease_first,
                lease_total,
                shards,
                manifest,
                runs,
            } => {
                e.u8(TAG_SHARD_ADMIT);
                e.time(*vtime);
                e.u64(*lease);
                e.u64(*lease_first);
                e.u64(*lease_total);
                e.u32(shards.len() as u32);
                for &s in shards {
                    e.u32(s);
                }
                e.opt_u64(*manifest);
                e.u32(runs.len() as u32);
                for run in runs {
                    e.u64(run.first_id);
                    e.u32(run.entries.len() as u32);
                    for a in &run.entries {
                        e.u32(a.index);
                        enc_manifest_entry(&mut e, &a.entry);
                    }
                }
            }
        }
        e.buf
    }

    /// Deserialize a frame payload.
    pub fn decode(buf: &[u8]) -> Result<JournalRecord, JournalError> {
        let mut d = Dec::new(buf);
        let rec = match d.u8("record tag")? {
            TAG_ADMIT => {
                let vtime = d.time("admit.vtime")?;
                let first_id = d.u64("admit.first_id")?;
                let total_jobs = d.u64("admit.total_jobs")?;
                let manifest = d.opt_u64("admit.manifest")?;
                let n = d.len("admit.entries")?;
                let mut entries = Vec::with_capacity(n);
                for _ in 0..n {
                    let index = d.u32("admit.entry.index")?;
                    entries.push(AdmitEntry {
                        index,
                        entry: dec_manifest_entry(&mut d)?,
                    });
                }
                JournalRecord::Admit {
                    vtime,
                    first_id,
                    total_jobs,
                    manifest,
                    entries,
                }
            }
            TAG_CANCEL => JournalRecord::Cancel {
                vtime: d.time("cancel.vtime")?,
                id: d.u64("cancel.id")?,
            },
            TAG_CHECKPOINT => {
                let vtime = d.time("cp.vtime")?;
                let next_id = d.u64("cp.next_id")?;
                let next_manifest_id = d.u64("cp.next_manifest_id")?;
                let njobs = d.len("cp.jobs")?;
                let mut jobs = Vec::with_capacity(njobs);
                for _ in 0..njobs {
                    let id = d.u64("cp.job.id")?;
                    let state = state_from(d.u8("cp.job.state")?)?;
                    let submit_time = d.time("cp.job.submit")?;
                    let requeue_count = d.u32("cp.job.requeues")?;
                    let spec = dec_spec(&mut d)?;
                    let nlog = d.len("cp.job.log")?;
                    let mut log = Vec::with_capacity(nlog);
                    for _ in 0..nlog {
                        let t = d.time("cp.job.log.time")?;
                        let code = d.u8("cp.job.log.kind")?;
                        let kind = LogKind::from_wire_code(code)
                            .ok_or_else(|| corrupt(format!("bad log-kind code {code}")))?;
                        log.push((t, kind));
                    }
                    jobs.push(CheckpointJob {
                        id,
                        state,
                        submit_time,
                        requeue_count,
                        spec,
                        log,
                    });
                }
                let nhist = d.len("cp.history")?;
                let mut history = Vec::with_capacity(nhist);
                for _ in 0..nhist {
                    history.push(dec_view(&mut d)?);
                }
                let nman = d.len("cp.manifests")?;
                let mut manifests = Vec::with_capacity(nman);
                for _ in 0..nman {
                    let id = d.u64("cp.manifest.id")?;
                    let nspans = d.len("cp.manifest.spans")?;
                    let mut spans = Vec::with_capacity(nspans);
                    for _ in 0..nspans {
                        spans.push(ManifestSpan {
                            index: d.u32("cp.span.index")?,
                            first: d.u64("cp.span.first")?,
                            count: d.u64("cp.span.count")?,
                            tag: d.opt_str("cp.span.tag")?.map(Arc::from),
                        });
                    }
                    let tag = spans.iter().find_map(|s| s.tag.clone());
                    manifests.push(RegisteredManifest { id, spans, tag });
                }
                let global_seq = d.u64("cp.global_seq")?;
                let applied_lease = d.u64("cp.applied_lease")?;
                JournalRecord::Checkpoint(CheckpointState {
                    vtime,
                    next_id,
                    next_manifest_id,
                    jobs,
                    history,
                    manifests,
                    global_seq,
                    applied_lease,
                })
            }
            TAG_SHARD_ADMIT => {
                let vtime = d.time("sadmit.vtime")?;
                let lease = d.u64("sadmit.lease")?;
                let lease_first = d.u64("sadmit.lease_first")?;
                let lease_total = d.u64("sadmit.lease_total")?;
                let nshards = d.len("sadmit.shards")?;
                let mut shards = Vec::with_capacity(nshards);
                for _ in 0..nshards {
                    shards.push(d.u32("sadmit.shard")?);
                }
                let manifest = d.opt_u64("sadmit.manifest")?;
                let nruns = d.len("sadmit.runs")?;
                let mut runs = Vec::with_capacity(nruns);
                for _ in 0..nruns {
                    let first_id = d.u64("sadmit.run.first_id")?;
                    let n = d.len("sadmit.run.entries")?;
                    let mut entries = Vec::with_capacity(n);
                    for _ in 0..n {
                        let index = d.u32("sadmit.run.entry.index")?;
                        entries.push(AdmitEntry {
                            index,
                            entry: dec_manifest_entry(&mut d)?,
                        });
                    }
                    runs.push(AdmitRun { first_id, entries });
                }
                JournalRecord::ShardAdmit {
                    vtime,
                    lease,
                    lease_first,
                    lease_total,
                    shards,
                    manifest,
                    runs,
                }
            }
            t => return Err(corrupt(format!("unknown record tag {t}"))),
        };
        d.finish("record")?;
        Ok(rec)
    }
}

fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

// -------------------------------------------------------------- segments

fn segment_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("seg-{seq:010}.wal"))
}

fn list_segments(dir: &Path) -> io::Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(seq) = name
            .strip_prefix("seg-")
            .and_then(|s| s.strip_suffix(".wal"))
            .and_then(|s| s.parse::<u64>().ok())
        {
            out.push((seq, entry.path()));
        }
    }
    out.sort();
    Ok(out)
}

/// Directory holding shard `idx`'s segments under a sharded journal root.
pub fn shard_journal_dir(dir: &Path, idx: usize) -> PathBuf {
    dir.join(format!("shard-{idx}"))
}

/// Path of the id-allocator log under a sharded journal root.
pub fn alloc_log_path(dir: &Path) -> PathBuf {
    dir.join("alloc.log")
}

/// Shard subdirectories (`shard-<i>/`) present under `dir`, ascending.
/// Empty for a missing dir or a flat (single-shard) layout.
pub fn list_shard_dirs(dir: &Path) -> Vec<(usize, PathBuf)> {
    let mut out = Vec::new();
    if let Ok(rd) = fs::read_dir(dir) {
        for entry in rd.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(idx) = name.strip_prefix("shard-").and_then(|s| s.parse::<usize>().ok()) {
                if entry.path().is_dir() {
                    out.push((idx, entry.path()));
                }
            }
        }
    }
    out.sort();
    out
}

/// Does `dir` hold a *sharded* journal layout (an allocator log or any
/// `shard-<i>/` subdirectory)?
pub fn dir_has_shard_layout(dir: &Path) -> bool {
    alloc_log_path(dir).exists() || !list_shard_dirs(dir).is_empty()
}

/// Does `dir` already hold journal state — flat segments, an allocator
/// log, or per-shard segment directories? (`false` for a missing or empty
/// directory — the daemon uses this to pick create vs recover.)
pub fn dir_has_segments(dir: &Path) -> bool {
    list_segments(dir).map(|v| !v.is_empty()).unwrap_or(false) || dir_has_shard_layout(dir)
}

/// Best-effort directory fsync (persists segment create/delete entries).
fn sync_dir(dir: &Path) {
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

struct Scan {
    records: Vec<JournalRecord>,
    /// Bytes up to and including the last intact record.
    valid_len: u64,
    /// Total file length (torn tail = `file_len - valid_len`).
    file_len: u64,
}

/// Scan one segment, stopping at the first torn/corrupt frame. `None` if
/// the magic itself is missing or torn (the whole segment is unusable).
fn scan_segment(path: &Path) -> Result<Option<Scan>, JournalError> {
    let data = fs::read(path)?;
    let file_len = data.len() as u64;
    if data.len() < JOURNAL_MAGIC.len() || &data[..JOURNAL_MAGIC.len()] != JOURNAL_MAGIC {
        return Ok(None);
    }
    let mut off = JOURNAL_MAGIC.len();
    let mut records = Vec::new();
    loop {
        if data.len() - off < 8 {
            break;
        }
        let len = u32::from_le_bytes(data[off..off + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(data[off + 4..off + 8].try_into().unwrap());
        if len == 0 || len > MAX_RECORD_LEN || data.len() - off - 8 < len {
            break;
        }
        let payload = &data[off + 8..off + 8 + len];
        if crc32(payload) != crc {
            break;
        }
        match JournalRecord::decode(payload) {
            Ok(r) => records.push(r),
            Err(_) => break,
        }
        off += 8 + len;
    }
    Ok(Some(Scan {
        records,
        valid_len: off as u64,
        file_len,
    }))
}

/// What `Journal::recover` found on disk.
#[derive(Debug)]
pub struct RecoveredJournal {
    /// The newest intact checkpoint.
    pub checkpoint: CheckpointState,
    /// Records appended after that checkpoint, oldest first.
    pub tail: Vec<JournalRecord>,
    /// Torn-tail bytes truncated from the surviving segment.
    pub torn_bytes: u64,
    /// Newer segments discarded whole (torn mid-checkpoint rotation).
    pub segments_discarded: usize,
}

// --------------------------------------------------------------- journal

/// An open write-ahead journal. All methods poison the handle on error:
/// once an append fails, nothing else may be acknowledged against it.
#[derive(Debug)]
pub struct Journal {
    dir: PathBuf,
    file: File,
    seg_seq: u64,
    /// Bytes written to the live segment.
    written_len: u64,
    /// Bytes covered by the last fsync.
    durable_len: u64,
    appends_since_sync: u32,
    records_since_checkpoint: u64,
    /// Monotone count of records appended via any path (group-commit
    /// waiters compare their append's sequence against `synced_seq`).
    append_seq: u64,
    /// `append_seq` value covered by the last fsync.
    synced_seq: u64,
    fsync: FsyncPolicy,
    faults: FaultPlan,
    poisoned: bool,
}

impl Journal {
    /// Create a fresh journal: one segment holding a genesis (empty-state)
    /// checkpoint, fsync'd regardless of policy. Fails with
    /// [`JournalError::NotEmpty`] if segments already exist — recover
    /// those instead of silently shadowing them.
    pub fn create(cfg: &DurabilityConfig) -> Result<Journal, JournalError> {
        fs::create_dir_all(&cfg.dir)?;
        if !list_segments(&cfg.dir)?.is_empty() {
            return Err(JournalError::NotEmpty(cfg.dir.clone()));
        }
        let seq = 1;
        let path = segment_path(&cfg.dir, seq);
        let mut file = OpenOptions::new()
            .create_new(true)
            .read(true)
            .write(true)
            .open(path)?;
        file.write_all(JOURNAL_MAGIC)?;
        let genesis = frame(&JournalRecord::Checkpoint(CheckpointState::genesis()).encode());
        file.write_all(&genesis)?;
        file.sync_data()?;
        sync_dir(&cfg.dir);
        let written = (JOURNAL_MAGIC.len() + genesis.len()) as u64;
        Ok(Journal {
            dir: cfg.dir.clone(),
            file,
            seg_seq: seq,
            written_len: written,
            durable_len: written,
            appends_since_sync: 0,
            records_since_checkpoint: 0,
            append_seq: 0,
            synced_seq: 0,
            fsync: cfg.fsync,
            faults: cfg.faults.clone(),
            poisoned: false,
        })
    }

    /// Recover a journal directory: pick the newest segment whose leading
    /// checkpoint is intact, truncate its torn tail, delete every other
    /// segment, and return the open journal plus what it held.
    pub fn recover(cfg: &DurabilityConfig) -> Result<(Journal, RecoveredJournal), JournalError> {
        let segments = list_segments(&cfg.dir)?;
        if segments.is_empty() {
            return Err(JournalError::Empty(cfg.dir.clone()));
        }
        let mut chosen: Option<(usize, Scan)> = None;
        let mut segments_discarded = 0usize;
        for (i, (_, path)) in segments.iter().enumerate().rev() {
            match scan_segment(path)? {
                Some(scan)
                    if matches!(scan.records.first(), Some(JournalRecord::Checkpoint(_))) =>
                {
                    chosen = Some((i, scan));
                    break;
                }
                _ => segments_discarded += 1,
            }
        }
        let Some((idx, scan)) = chosen else {
            return Err(corrupt("no segment with an intact leading checkpoint"));
        };
        for (j, (_, path)) in segments.iter().enumerate() {
            if j != idx {
                let _ = fs::remove_file(path);
            }
        }
        let (seq, path) = &segments[idx];
        let mut file = OpenOptions::new().read(true).write(true).open(path)?;
        file.set_len(scan.valid_len)?;
        file.sync_all()?;
        file.seek(SeekFrom::End(0))?;
        sync_dir(&cfg.dir);
        let mut records = scan.records.into_iter();
        let checkpoint = match records.next() {
            Some(JournalRecord::Checkpoint(cp)) => cp,
            _ => unreachable!("chosen segment verified to lead with a checkpoint"),
        };
        let tail: Vec<JournalRecord> = records.collect();
        let journal = Journal {
            dir: cfg.dir.clone(),
            file,
            seg_seq: *seq,
            written_len: scan.valid_len,
            durable_len: scan.valid_len,
            appends_since_sync: 0,
            records_since_checkpoint: tail.len() as u64,
            append_seq: 0,
            synced_seq: 0,
            fsync: cfg.fsync,
            faults: cfg.faults.clone(),
            poisoned: false,
        };
        let recovered = RecoveredJournal {
            checkpoint,
            torn_bytes: scan.file_len - scan.valid_len,
            segments_discarded,
            tail,
        };
        Ok((journal, recovered))
    }

    /// Append one record (and fsync it, per policy). On `Err` the journal
    /// is poisoned and the caller must not acknowledge the mutation.
    pub fn append(&mut self, rec: &JournalRecord) -> Result<(), JournalError> {
        if self.poisoned {
            return Err(JournalError::Poisoned);
        }
        let r = self.append_inner(rec);
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn append_inner(&mut self, rec: &JournalRecord) -> Result<(), JournalError> {
        let framed = frame(&rec.encode());
        self.file.write_all(&framed)?;
        self.written_len += framed.len() as u64;
        self.appends_since_sync += 1;
        self.records_since_checkpoint += 1;
        self.append_seq += 1;
        if self.faults.take(FaultPoint::AfterAppend) {
            // Power cut before the fsync: everything past the last durable
            // byte is page cache that never hit the platter. Truncate it
            // away so the "restarted" daemon sees what a real crash would
            // leave.
            let _ = self.file.set_len(self.durable_len);
            let _ = self.file.sync_all();
            return Err(JournalError::Fault("after-append"));
        }
        let due = match self.fsync {
            FsyncPolicy::Always => true,
            FsyncPolicy::Interval { appends } => self.appends_since_sync >= appends,
            FsyncPolicy::Never => false,
        };
        if due {
            self.sync_inner()?;
        }
        if self.faults.take(FaultPoint::AfterFsync) {
            // The crash lands after durability but before the publish/ack:
            // force the sync (whatever the policy) so the record is
            // exactly the documented at-least-once survivor.
            self.sync_inner()?;
            return Err(JournalError::Fault("after-fsync"));
        }
        Ok(())
    }

    /// Append one record **without** the per-record policy sync: the
    /// group-commit path. Returns the record's append sequence; the caller
    /// must not acknowledge until [`Journal::synced_seq`] reaches it (via
    /// [`Journal::group_sync`], typically run by a leader writer batching
    /// several waiters into one fsync). On `Err` the journal is poisoned.
    pub fn append_deferred(&mut self, rec: &JournalRecord) -> Result<u64, JournalError> {
        if self.poisoned {
            return Err(JournalError::Poisoned);
        }
        let r = self.append_deferred_inner(rec);
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn append_deferred_inner(&mut self, rec: &JournalRecord) -> Result<u64, JournalError> {
        let framed = frame(&rec.encode());
        self.file.write_all(&framed)?;
        self.written_len += framed.len() as u64;
        self.appends_since_sync += 1;
        self.records_since_checkpoint += 1;
        self.append_seq += 1;
        if self.faults.take(FaultPoint::AfterAppend) {
            // Same power-cut model as `append_inner`: drop the page-cache
            // bytes so the restarted daemon sees what a crash would leave.
            let _ = self.file.set_len(self.durable_len);
            let _ = self.file.sync_all();
            return Err(JournalError::Fault("after-append"));
        }
        Ok(self.append_seq)
    }

    /// Fsync everything appended so far on behalf of a group of deferred
    /// writers, returning the new [`Journal::synced_seq`]. The AfterFsync
    /// fault fires here (post-durability, pre-ack), matching `append`.
    pub fn group_sync(&mut self) -> Result<u64, JournalError> {
        if self.poisoned {
            return Err(JournalError::Poisoned);
        }
        let r = self.group_sync_inner();
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn group_sync_inner(&mut self) -> Result<u64, JournalError> {
        self.sync_inner()?;
        if self.faults.take(FaultPoint::AfterFsync) {
            return Err(JournalError::Fault("after-fsync"));
        }
        Ok(self.synced_seq)
    }

    /// Sequence of the last appended record (deferred or not).
    pub fn append_seq(&self) -> u64 {
        self.append_seq
    }

    /// Highest append sequence covered by an fsync.
    pub fn synced_seq(&self) -> u64 {
        self.synced_seq
    }

    /// Force an fsync of everything appended so far.
    pub fn sync(&mut self) -> Result<(), JournalError> {
        if self.poisoned {
            return Err(JournalError::Poisoned);
        }
        let r = self.sync_inner();
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn sync_inner(&mut self) -> Result<(), JournalError> {
        if self.durable_len != self.written_len {
            self.file.sync_data()?;
            self.durable_len = self.written_len;
        }
        self.appends_since_sync = 0;
        self.synced_seq = self.append_seq;
        Ok(())
    }

    /// Should the caller checkpoint now?
    pub fn checkpoint_due(&self, cfg: &DurabilityConfig) -> bool {
        self.records_since_checkpoint >= cfg.checkpoint_every
            || self.written_len >= cfg.max_segment_bytes
    }

    /// Write `state` as the head of a fresh segment, fsync it, then delete
    /// the older segments (checkpoint-truncation). Always synced,
    /// whatever the append policy: history is about to be deleted.
    pub fn checkpoint(&mut self, state: &CheckpointState) -> Result<(), JournalError> {
        if self.poisoned {
            return Err(JournalError::Poisoned);
        }
        let r = self.checkpoint_inner(state);
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn checkpoint_inner(&mut self, state: &CheckpointState) -> Result<(), JournalError> {
        let new_seq = self.seg_seq + 1;
        let path = segment_path(&self.dir, new_seq);
        let mut file = OpenOptions::new()
            .create_new(true)
            .read(true)
            .write(true)
            .open(path)?;
        file.write_all(JOURNAL_MAGIC)?;
        let framed = frame(&JournalRecord::Checkpoint(state.clone()).encode());
        if self.faults.take(FaultPoint::MidCheckpoint) {
            // Crash halfway through the rotation: the new segment is torn
            // and the old ones still exist — recovery must fall back.
            file.write_all(&framed[..framed.len() / 2])?;
            let _ = file.sync_data();
            return Err(JournalError::Fault("mid-checkpoint"));
        }
        file.write_all(&framed)?;
        file.sync_data()?;
        sync_dir(&self.dir);
        self.file = file;
        self.seg_seq = new_seq;
        self.written_len = (JOURNAL_MAGIC.len() + framed.len()) as u64;
        self.durable_len = self.written_len;
        self.appends_since_sync = 0;
        self.records_since_checkpoint = 0;
        // Rotation absorbs every prior append into the durable checkpoint.
        self.synced_seq = self.append_seq;
        for (seq, path) in list_segments(&self.dir)? {
            if seq < new_seq {
                let _ = fs::remove_file(path);
            }
        }
        sync_dir(&self.dir);
        Ok(())
    }

    /// Bytes written to the live segment.
    pub fn segment_bytes(&self) -> u64 {
        self.written_len
    }

    /// Bytes of the live segment covered by fsync.
    pub fn durable_bytes(&self) -> u64 {
        self.durable_len
    }

    /// Records appended since the segment's leading checkpoint.
    pub fn records_since_checkpoint(&self) -> u64 {
        self.records_since_checkpoint
    }

    /// Live segment sequence number.
    pub fn segment_seq(&self) -> u64 {
        self.seg_seq
    }

    /// Has a previous error poisoned this handle?
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }
}

// ------------------------------------------------------------- alloc log

/// Leading bytes of the allocator log.
pub const ALLOC_MAGIC: &[u8; 8] = b"SPOTALC1";

/// One id-range lease: the allocator handed `[first, first + count)` to a
/// sharded admission under lease sequence `lease`. Fsync'd before any of
/// those ids appears in a shard journal, so recovery's id watermark is
/// always ahead of every id a shard journal can mention.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocLease {
    /// Lease sequence (monotone; allocated under the admission's shard
    /// locks).
    pub lease: u64,
    /// First job id in the leased range.
    pub first: u64,
    /// Number of ids leased.
    pub count: u64,
}

impl AllocLease {
    fn encode(&self) -> [u8; 24] {
        let mut out = [0u8; 24];
        out[..8].copy_from_slice(&self.lease.to_le_bytes());
        out[8..16].copy_from_slice(&self.first.to_le_bytes());
        out[16..24].copy_from_slice(&self.count.to_le_bytes());
        out
    }

    fn decode(buf: &[u8]) -> Result<AllocLease, JournalError> {
        if buf.len() != 24 {
            return Err(corrupt(format!("alloc lease payload len {}", buf.len())));
        }
        Ok(AllocLease {
            lease: u64::from_le_bytes(buf[..8].try_into().unwrap()),
            first: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            count: u64::from_le_bytes(buf[16..24].try_into().unwrap()),
        })
    }
}

/// The id-allocator log of a sharded journal: a single append-only file of
/// [`AllocLease`] records (same frame format as the WAL, magic
/// [`ALLOC_MAGIC`]). Appends fsync inline per the configured policy — the
/// log is tiny (24-byte payloads) and written once per admission, so it
/// does not join the group-commit protocol. Poisons like [`Journal`].
#[derive(Debug)]
pub struct AllocLog {
    path: PathBuf,
    file: File,
    written_len: u64,
    durable_len: u64,
    appends_since_sync: u32,
    fsync: FsyncPolicy,
    faults: FaultPlan,
    poisoned: bool,
    /// Highest `first + count` across every lease ever appended (including
    /// the compaction watermark record).
    watermark_id: u64,
    /// Highest lease sequence ever appended.
    watermark_lease: u64,
}

impl AllocLog {
    /// Create a fresh allocator log at `alloc.log` under `dir`.
    pub fn create(cfg: &DurabilityConfig) -> Result<AllocLog, JournalError> {
        fs::create_dir_all(&cfg.dir)?;
        let path = alloc_log_path(&cfg.dir);
        let mut file = OpenOptions::new()
            .create_new(true)
            .read(true)
            .write(true)
            .open(&path)?;
        file.write_all(ALLOC_MAGIC)?;
        file.sync_data()?;
        sync_dir(&cfg.dir);
        let written = ALLOC_MAGIC.len() as u64;
        Ok(AllocLog {
            path,
            file,
            written_len: written,
            durable_len: written,
            appends_since_sync: 0,
            fsync: cfg.fsync,
            faults: cfg.faults.clone(),
            poisoned: false,
            watermark_id: 0,
            watermark_lease: 0,
        })
    }

    /// Recover the allocator log: scan intact lease frames, truncate any
    /// torn tail, and return the open log plus the surviving leases
    /// (oldest first).
    pub fn recover(cfg: &DurabilityConfig) -> Result<(AllocLog, Vec<AllocLease>), JournalError> {
        let path = alloc_log_path(&cfg.dir);
        let data = fs::read(&path)?;
        if data.len() < ALLOC_MAGIC.len() || &data[..ALLOC_MAGIC.len()] != ALLOC_MAGIC {
            return Err(corrupt("allocator log magic missing or torn"));
        }
        let mut off = ALLOC_MAGIC.len();
        let mut leases = Vec::new();
        loop {
            if data.len() - off < 8 {
                break;
            }
            let len = u32::from_le_bytes(data[off..off + 4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(data[off + 4..off + 8].try_into().unwrap());
            if len == 0 || len > MAX_RECORD_LEN || data.len() - off - 8 < len {
                break;
            }
            let payload = &data[off + 8..off + 8 + len];
            if crc32(payload) != crc {
                break;
            }
            match AllocLease::decode(payload) {
                Ok(l) => leases.push(l),
                Err(_) => break,
            }
            off += 8 + len;
        }
        let valid_len = off as u64;
        let mut file = OpenOptions::new().read(true).write(true).open(&path)?;
        file.set_len(valid_len)?;
        file.sync_all()?;
        file.seek(SeekFrom::End(0))?;
        let watermark_id = leases.iter().map(|l| l.first + l.count).max().unwrap_or(0);
        let watermark_lease = leases.iter().map(|l| l.lease).max().unwrap_or(0);
        let log = AllocLog {
            path,
            file,
            written_len: valid_len,
            durable_len: valid_len,
            appends_since_sync: 0,
            fsync: cfg.fsync,
            faults: cfg.faults.clone(),
            poisoned: false,
            watermark_id,
            watermark_lease,
        };
        Ok((log, leases))
    }

    /// Append one lease (and fsync per policy). On `Err` the log is
    /// poisoned and the admission must abort before any shard-journal
    /// append or scheduler mutation.
    pub fn append(&mut self, lease: AllocLease) -> Result<(), JournalError> {
        if self.poisoned {
            return Err(JournalError::Poisoned);
        }
        let r = self.append_inner(lease);
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn append_inner(&mut self, lease: AllocLease) -> Result<(), JournalError> {
        let framed = frame(&lease.encode());
        if self.faults.take(FaultPoint::AllocAppend) {
            // Torn lease: half the frame hits the file, then the "machine
            // dies". Recovery must truncate it and treat the admission as
            // never having happened.
            self.file.write_all(&framed[..framed.len() / 2])?;
            let _ = self.file.sync_data();
            return Err(JournalError::Fault("alloc-append"));
        }
        self.file.write_all(&framed)?;
        self.written_len += framed.len() as u64;
        self.appends_since_sync += 1;
        self.watermark_id = self.watermark_id.max(lease.first + lease.count);
        self.watermark_lease = self.watermark_lease.max(lease.lease);
        let due = match self.fsync {
            FsyncPolicy::Always => true,
            FsyncPolicy::Interval { appends } => self.appends_since_sync >= appends,
            FsyncPolicy::Never => false,
        };
        if due {
            self.sync_inner()?;
        }
        Ok(())
    }

    /// Force an fsync of everything appended so far.
    pub fn sync(&mut self) -> Result<(), JournalError> {
        if self.poisoned {
            return Err(JournalError::Poisoned);
        }
        let r = self.sync_inner();
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn sync_inner(&mut self) -> Result<(), JournalError> {
        if self.durable_len != self.written_len {
            self.file.sync_data()?;
            self.durable_len = self.written_len;
        }
        self.appends_since_sync = 0;
        Ok(())
    }

    /// Compact: rewrite the log as magic + one watermark record covering
    /// everything seen so far, fsync'd. Safe any time the shard journals
    /// have checkpointed/replayed past the dropped leases — recovery only
    /// needs the watermark to stay ahead of every journaled id.
    pub fn compact(&mut self) -> Result<(), JournalError> {
        if self.poisoned {
            return Err(JournalError::Poisoned);
        }
        let r = self.compact_inner();
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn compact_inner(&mut self) -> Result<(), JournalError> {
        let watermark = AllocLease {
            lease: self.watermark_lease,
            first: self.watermark_id,
            count: 0,
        };
        let framed = frame(&watermark.encode());
        self.file.seek(SeekFrom::Start(0))?;
        self.file.set_len(0)?;
        self.file.write_all(ALLOC_MAGIC)?;
        self.file.write_all(&framed)?;
        self.file.sync_data()?;
        self.written_len = (ALLOC_MAGIC.len() + framed.len()) as u64;
        self.durable_len = self.written_len;
        self.appends_since_sync = 0;
        Ok(())
    }

    /// Highest `first + count` over every appended lease: the id
    /// watermark recovery floors `next_id` at.
    pub fn watermark_id(&self) -> u64 {
        self.watermark_id
    }

    /// Highest lease sequence appended.
    pub fn watermark_lease(&self) -> u64 {
        self.watermark_lease
    }

    /// Path of the backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Has a previous error poisoned this handle?
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::crash::{faulty_durability, TempDir};

    fn cfg(dir: &TempDir, fsync: FsyncPolicy) -> DurabilityConfig {
        DurabilityConfig::new(dir.path()).with_fsync(fsync)
    }

    fn admit(vtime_secs: u64, first_id: u64, manifest: Option<u64>) -> JournalRecord {
        let entry = ManifestEntry::new(QosClass::Normal, JobType::Array, 8, 1)
            .with_count(2)
            .with_tag("burst");
        JournalRecord::Admit {
            vtime: SimTime::from_secs(vtime_secs),
            first_id,
            total_jobs: 2,
            manifest,
            entries: vec![AdmitEntry { index: 0, entry }],
        }
    }

    fn sample_checkpoint() -> CheckpointState {
        let spec = JobSpec::spot(UserId(9), JobType::TripleMode, 64).with_tag("cp-tag");
        CheckpointState {
            vtime: SimTime::from_secs(120),
            next_id: 42,
            next_manifest_id: 5,
            jobs: vec![CheckpointJob {
                id: 41,
                state: JobState::Running,
                submit_time: SimTime::from_secs(100),
                requeue_count: 1,
                spec,
                log: vec![
                    (SimTime::from_secs(100), LogKind::Recognized),
                    (SimTime::from_secs(101), LogKind::DispatchDone),
                ],
            }],
            history: vec![JobView {
                id: 7,
                job_type: JobType::Individual,
                tasks: 1,
                user: 3,
                qos: QosClass::Normal,
                state: JobState::Completed,
                submit_secs: 1.0,
                queue_secs: 1.0,
                start_secs: Some(2.0),
                end_secs: Some(3.0),
                requeues: 0,
                recognized: Some(SimTime::from_secs(1)),
                dispatched: Some(SimTime::from_secs(2)),
                tag: Arc::from("old"),
                revision: 4,
            }],
            manifests: vec![RegisteredManifest {
                id: 4,
                spans: vec![ManifestSpan {
                    index: 0,
                    first: 30,
                    count: 12,
                    tag: Some(Arc::from("burst")),
                }],
                tag: Some(Arc::from("burst")),
            }],
            global_seq: 3,
            applied_lease: 2,
        }
    }

    fn shard_admit(lease: u64, shards: Vec<u32>) -> JournalRecord {
        let entry = ManifestEntry::new(QosClass::High, JobType::Array, 4, 1)
            .with_count(3)
            .with_tag("xshard");
        JournalRecord::ShardAdmit {
            vtime: SimTime::from_secs(lease),
            lease,
            lease_first: 100,
            lease_total: 6,
            shards,
            manifest: Some(9),
            runs: vec![AdmitRun {
                first_id: 100,
                entries: vec![AdmitEntry { index: 2, entry }],
            }],
        }
    }

    #[test]
    fn crc32_reference_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn records_roundtrip() {
        for rec in [
            admit(3, 10, Some(2)),
            admit(0, 1, None),
            JournalRecord::Cancel {
                vtime: SimTime::from_secs(9),
                id: 7,
            },
            JournalRecord::Checkpoint(sample_checkpoint()),
            JournalRecord::Checkpoint(CheckpointState::genesis()),
            shard_admit(5, vec![0, 1]),
            shard_admit(6, vec![1]),
        ] {
            let bytes = rec.encode();
            let back = JournalRecord::decode(&bytes).expect("decode");
            assert_eq!(back, rec);
        }
    }

    #[test]
    fn decode_rejects_garbage_and_truncation() {
        assert!(JournalRecord::decode(&[]).is_err());
        assert!(JournalRecord::decode(&[99, 0, 0]).is_err());
        let good = admit(3, 10, Some(2)).encode();
        for cut in [1, good.len() / 2, good.len() - 1] {
            assert!(JournalRecord::decode(&good[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing junk after a well-formed record is corruption too.
        let mut padded = good.clone();
        padded.push(0);
        assert!(JournalRecord::decode(&padded).is_err());
    }

    #[test]
    fn create_append_recover_roundtrip() {
        let dir = TempDir::new("wal-roundtrip");
        let c = cfg(&dir, FsyncPolicy::Always);
        let recs = [
            admit(1, 1, Some(1)),
            JournalRecord::Cancel {
                vtime: SimTime::from_secs(2),
                id: 1,
            },
            admit(3, 3, None),
        ];
        {
            let mut j = Journal::create(&c).expect("create");
            for r in &recs {
                j.append(r).expect("append");
            }
            assert_eq!(j.records_since_checkpoint(), 3);
            assert_eq!(j.durable_bytes(), j.segment_bytes());
        }
        assert!(dir_has_segments(dir.path()));
        let (j2, recovered) = Journal::recover(&c).expect("recover");
        assert_eq!(recovered.checkpoint, CheckpointState::genesis());
        assert_eq!(recovered.tail, recs);
        assert_eq!(recovered.torn_bytes, 0);
        assert_eq!(recovered.segments_discarded, 0);
        assert_eq!(j2.records_since_checkpoint(), 3);
    }

    #[test]
    fn create_refuses_nonempty_and_recover_refuses_empty() {
        let dir = TempDir::new("wal-guards");
        let c = cfg(&dir, FsyncPolicy::Always);
        assert!(matches!(
            Journal::recover(&c),
            Err(JournalError::Empty(_))
        ));
        drop(Journal::create(&c).expect("create"));
        assert!(matches!(
            Journal::create(&c),
            Err(JournalError::NotEmpty(_))
        ));
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let dir = TempDir::new("wal-torn");
        let c = cfg(&dir, FsyncPolicy::Always);
        {
            let mut j = Journal::create(&c).expect("create");
            j.append(&admit(1, 1, None)).expect("append");
        }
        // Simulate a crash mid-append: a frame header promising more bytes
        // than exist, followed by junk.
        let seg = list_segments(dir.path()).unwrap()[0].1.clone();
        let mut f = OpenOptions::new().append(true).open(&seg).unwrap();
        f.write_all(&500u32.to_le_bytes()).unwrap();
        f.write_all(&0xDEAD_BEEFu32.to_le_bytes()).unwrap();
        f.write_all(b"torn").unwrap();
        drop(f);
        let (mut j2, recovered) = Journal::recover(&c).expect("recover");
        assert_eq!(recovered.tail, vec![admit(1, 1, None)]);
        assert_eq!(recovered.torn_bytes, 12);
        // The journal is usable after truncation: append and recover again.
        j2.append(&admit(2, 2, None)).expect("append after recover");
        drop(j2);
        let (_, again) = Journal::recover(&c).expect("second recover");
        assert_eq!(again.tail.len(), 2);
        assert_eq!(again.torn_bytes, 0);
    }

    #[test]
    fn corrupted_crc_cuts_the_tail_there() {
        let dir = TempDir::new("wal-crc");
        let c = cfg(&dir, FsyncPolicy::Always);
        {
            let mut j = Journal::create(&c).expect("create");
            j.append(&admit(1, 1, None)).expect("append");
            j.append(&admit(2, 3, None)).expect("append");
        }
        // Flip one byte in the LAST record's payload: the scan must keep
        // the first record and drop the damaged one.
        let seg = list_segments(dir.path()).unwrap()[0].1.clone();
        let mut data = fs::read(&seg).unwrap();
        let last = data.len() - 1;
        data[last] ^= 0xFF;
        fs::write(&seg, &data).unwrap();
        let (_, recovered) = Journal::recover(&c).expect("recover");
        assert_eq!(recovered.tail, vec![admit(1, 1, None)]);
        assert!(recovered.torn_bytes > 0);
    }

    #[test]
    fn checkpoint_rotates_and_truncates() {
        let dir = TempDir::new("wal-rotate");
        let c = cfg(&dir, FsyncPolicy::Always);
        let cp = sample_checkpoint();
        {
            let mut j = Journal::create(&c).expect("create");
            for i in 0..3 {
                j.append(&admit(i, i * 2 + 1, None)).expect("append");
            }
            j.checkpoint(&cp).expect("checkpoint");
            assert_eq!(j.segment_seq(), 2);
            assert_eq!(j.records_since_checkpoint(), 0);
            j.append(&admit(9, 9, None)).expect("append post-rotate");
        }
        let segs = list_segments(dir.path()).unwrap();
        assert_eq!(segs.len(), 1, "older segments must be deleted");
        assert_eq!(segs[0].0, 2);
        let (_, recovered) = Journal::recover(&c).expect("recover");
        assert_eq!(recovered.checkpoint, cp);
        assert_eq!(recovered.tail, vec![admit(9, 9, None)]);
    }

    #[test]
    fn checkpoint_due_by_records_and_bytes() {
        let dir = TempDir::new("wal-due");
        let mut c = cfg(&dir, FsyncPolicy::Never).with_checkpoint_every(2);
        let mut j = Journal::create(&c).expect("create");
        assert!(!j.checkpoint_due(&c));
        j.append(&admit(1, 1, None)).expect("append");
        assert!(!j.checkpoint_due(&c));
        j.append(&admit(2, 3, None)).expect("append");
        assert!(j.checkpoint_due(&c), "record stride reached");
        c.checkpoint_every = 1_000_000;
        assert!(!j.checkpoint_due(&c));
        c.max_segment_bytes = 1;
        assert!(j.checkpoint_due(&c), "byte cap reached");
    }

    #[test]
    fn interval_policy_defers_durability() {
        let dir = TempDir::new("wal-interval");
        let c = cfg(&dir, FsyncPolicy::Interval { appends: 2 });
        let mut j = Journal::create(&c).expect("create");
        j.append(&admit(1, 1, None)).expect("append");
        assert!(j.durable_bytes() < j.segment_bytes(), "first append unsynced");
        j.append(&admit(2, 3, None)).expect("append");
        assert_eq!(j.durable_bytes(), j.segment_bytes(), "stride hit syncs");
        j.append(&admit(3, 5, None)).expect("append");
        j.sync().expect("manual sync");
        assert_eq!(j.durable_bytes(), j.segment_bytes());
    }

    #[test]
    fn fault_after_append_loses_the_unsynced_record() {
        let dir = TempDir::new("wal-fault-append");
        let c = faulty_durability(dir.path(), FsyncPolicy::Always, FaultPoint::AfterAppend);
        let mut j = Journal::create(&c).expect("create");
        j.append(&admit(1, 1, None)).expect("first append survives");
        let err = j.append(&admit(2, 3, None)).expect_err("armed fault fires");
        assert!(matches!(err, JournalError::Fault("after-append")));
        assert!(j.is_poisoned());
        assert!(matches!(
            j.append(&admit(3, 5, None)),
            Err(JournalError::Poisoned)
        ));
        drop(j);
        let (_, recovered) = Journal::recover(&c).expect("recover");
        // The un-fsync'd record is gone; the acked one survives. Nothing
        // torn remains on disk (the fault truncated it, as a power cut
        // would have).
        assert_eq!(recovered.tail, vec![admit(1, 1, None)]);
        assert_eq!(recovered.torn_bytes, 0);
    }

    #[test]
    fn fault_after_fsync_keeps_the_unacked_record() {
        let dir = TempDir::new("wal-fault-fsync");
        // Policy `Never`: only the fault's forced sync makes it durable.
        let c = faulty_durability(dir.path(), FsyncPolicy::Never, FaultPoint::AfterFsync);
        let mut j = Journal::create(&c).expect("create");
        j.append(&admit(1, 1, None)).expect("append");
        let err = j.append(&admit(2, 3, None)).expect_err("armed fault fires");
        assert!(matches!(err, JournalError::Fault("after-fsync")));
        drop(j);
        let (_, recovered) = Journal::recover(&c).expect("recover");
        // Both records durable: the second is the documented at-least-once
        // resurrection (durable but never acked).
        assert_eq!(recovered.tail.len(), 2);
    }

    #[test]
    fn fault_mid_checkpoint_falls_back_to_previous_segment() {
        let dir = TempDir::new("wal-fault-cp");
        let c = faulty_durability(dir.path(), FsyncPolicy::Always, FaultPoint::MidCheckpoint);
        let mut j = Journal::create(&c).expect("create");
        j.append(&admit(1, 1, None)).expect("append");
        let err = j
            .checkpoint(&sample_checkpoint())
            .expect_err("armed fault fires");
        assert!(matches!(err, JournalError::Fault("mid-checkpoint")));
        // Torn new segment and intact old one coexist on disk.
        assert_eq!(list_segments(dir.path()).unwrap().len(), 2);
        drop(j);
        let (j2, recovered) = Journal::recover(&c).expect("recover");
        assert_eq!(recovered.segments_discarded, 1);
        assert_eq!(recovered.checkpoint, CheckpointState::genesis());
        assert_eq!(recovered.tail, vec![admit(1, 1, None)]);
        // The torn segment was deleted; the survivor is segment 1.
        assert_eq!(list_segments(dir.path()).unwrap().len(), 1);
        assert_eq!(j2.segment_seq(), 1);
    }

    #[test]
    fn fsync_policy_parses_cli_forms() {
        assert_eq!(FsyncPolicy::parse("always"), Some(FsyncPolicy::Always));
        assert_eq!(FsyncPolicy::parse("never"), Some(FsyncPolicy::Never));
        assert_eq!(FsyncPolicy::parse("interval"), Some(FsyncPolicy::default()));
        assert_eq!(
            FsyncPolicy::parse("interval:8"),
            Some(FsyncPolicy::Interval { appends: 8 })
        );
        for bad in ["", "interval:0", "interval:x", "sometimes"] {
            assert_eq!(FsyncPolicy::parse(bad), None, "{bad:?}");
        }
        assert_eq!(FsyncPolicy::Always.label(), "always");
        assert_eq!(FsyncPolicy::default().label(), "interval");
    }

    #[test]
    fn countdown_fault_skips_then_fires_once() {
        let plan = FaultPlan::new();
        assert!(!plan.take(FaultPoint::AfterAppend), "disarmed never fires");
        plan.arm_after(FaultPoint::AfterAppend, 2);
        assert!(plan.armed(FaultPoint::AfterAppend));
        assert!(!plan.take(FaultPoint::AfterAppend), "hit 1 passes");
        assert!(!plan.take(FaultPoint::AfterAppend), "hit 2 passes");
        assert!(plan.take(FaultPoint::AfterAppend), "hit 3 fires");
        assert!(!plan.armed(FaultPoint::AfterAppend), "firing disarms");
        assert!(!plan.take(FaultPoint::AfterAppend));
        plan.arm(FaultPoint::AllocAppend);
        assert!(plan.take(FaultPoint::AllocAppend), "arm = fire on next hit");
    }

    #[test]
    fn shard_targeted_fault_ignores_other_shards_hits() {
        let dir = TempDir::new("wal-targeted-fault");
        let root = DurabilityConfig::new(dir.path());
        let shard0 = root.for_shard(0).faults;
        let shard1 = root.for_shard(1).faults;
        root.faults.arm_for_shard(1, FaultPoint::AfterAppend);
        assert!(shard0.armed(FaultPoint::AfterAppend), "arms are shared");
        // Shard 0 can hammer the point: the countdown is not consumed.
        for _ in 0..3 {
            assert!(!shard0.take(FaultPoint::AfterAppend), "wrong shard passes");
        }
        assert!(shard1.armed(FaultPoint::AfterAppend));
        assert!(shard1.take(FaultPoint::AfterAppend), "targeted shard fires");
        assert!(!shard1.armed(FaultPoint::AfterAppend), "firing disarms");
        // Firing also cleared the target: a plain `arm` now fires for any
        // hitter, shard-scoped clone or not.
        root.faults.arm(FaultPoint::AfterAppend);
        assert!(shard0.take(FaultPoint::AfterAppend), "untargeted again");
    }

    #[test]
    fn shard_layout_helpers_detect_both_layouts() {
        let dir = TempDir::new("wal-layout");
        assert!(!dir_has_segments(dir.path()));
        assert!(!dir_has_shard_layout(dir.path()));
        let shard_cfg = DurabilityConfig::new(dir.path()).for_shard(1);
        assert_eq!(shard_cfg.dir, shard_journal_dir(dir.path(), 1));
        drop(Journal::create(&shard_cfg).expect("create shard journal"));
        assert!(dir_has_shard_layout(dir.path()));
        assert!(dir_has_segments(dir.path()), "sharded layout counts");
        assert_eq!(list_shard_dirs(dir.path()), vec![(1, shard_journal_dir(dir.path(), 1))]);
        // Flat layout: only seg files, no alloc log / shard dirs.
        let flat = TempDir::new("wal-layout-flat");
        drop(Journal::create(&DurabilityConfig::new(flat.path())).expect("create"));
        assert!(dir_has_segments(flat.path()));
        assert!(!dir_has_shard_layout(flat.path()));
    }

    #[test]
    fn alloc_log_roundtrips_and_truncates_torn_tail() {
        let dir = TempDir::new("alloc-roundtrip");
        let c = cfg(&dir, FsyncPolicy::Always);
        let leases = [
            AllocLease { lease: 1, first: 1, count: 4 },
            AllocLease { lease: 2, first: 5, count: 2 },
        ];
        {
            let mut a = AllocLog::create(&c).expect("create");
            for l in &leases {
                a.append(*l).expect("append");
            }
            assert_eq!(a.watermark_id(), 7);
            assert_eq!(a.watermark_lease(), 2);
        }
        // Torn half-frame at the tail must truncate cleanly.
        {
            let mut f = OpenOptions::new()
                .append(true)
                .open(alloc_log_path(dir.path()))
                .unwrap();
            f.write_all(&[24, 0, 0, 0, 0xAA]).unwrap();
        }
        let (a2, back) = AllocLog::recover(&c).expect("recover");
        assert_eq!(back, leases);
        assert_eq!(a2.watermark_id(), 7);
        assert_eq!(a2.watermark_lease(), 2);
    }

    #[test]
    fn alloc_log_fault_tears_the_lease() {
        let dir = TempDir::new("alloc-fault");
        let c = faulty_durability(dir.path(), FsyncPolicy::Always, FaultPoint::AllocAppend);
        let mut a = AllocLog::create(&c).expect("create");
        a.append(AllocLease { lease: 1, first: 1, count: 3 })
            .expect("first append survives");
        let err = a
            .append(AllocLease { lease: 2, first: 4, count: 3 })
            .expect_err("armed fault fires");
        assert!(matches!(err, JournalError::Fault("alloc-append")));
        assert!(a.is_poisoned());
        drop(a);
        let (a2, back) = AllocLog::recover(&c).expect("recover");
        assert_eq!(back, vec![AllocLease { lease: 1, first: 1, count: 3 }]);
        assert_eq!(a2.watermark_id(), 4, "torn lease never raises the watermark");
    }

    #[test]
    fn alloc_log_compact_preserves_watermarks() {
        let dir = TempDir::new("alloc-compact");
        let c = cfg(&dir, FsyncPolicy::Never);
        let mut a = AllocLog::create(&c).expect("create");
        for i in 0..50u64 {
            a.append(AllocLease { lease: i + 1, first: i * 10 + 1, count: 10 })
                .expect("append");
        }
        let before = fs::metadata(a.path()).unwrap().len();
        a.compact().expect("compact");
        let after = fs::metadata(a.path()).unwrap().len();
        assert!(after < before, "compaction must shrink the log");
        drop(a);
        let (a2, back) = AllocLog::recover(&c).expect("recover");
        assert_eq!(back.len(), 1, "one watermark record survives");
        assert_eq!(a2.watermark_id(), 491);
        assert_eq!(a2.watermark_lease(), 50);
    }

    #[test]
    fn deferred_appends_batch_into_one_group_sync() {
        let dir = TempDir::new("wal-group");
        let c = cfg(&dir, FsyncPolicy::Always);
        let mut j = Journal::create(&c).expect("create");
        let s1 = j.append_deferred(&admit(1, 1, None)).expect("defer 1");
        let s2 = j.append_deferred(&admit(2, 3, None)).expect("defer 2");
        assert_eq!((s1, s2), (1, 2));
        assert_eq!(j.synced_seq(), 0, "deferred appends do not sync");
        assert!(j.durable_bytes() < j.segment_bytes());
        let synced = j.group_sync().expect("group sync");
        assert_eq!(synced, 2, "one fsync covers both writers");
        assert_eq!(j.synced_seq(), j.append_seq());
        assert_eq!(j.durable_bytes(), j.segment_bytes());
        drop(j);
        let (_, recovered) = Journal::recover(&c).expect("recover");
        assert_eq!(recovered.tail.len(), 2);
    }

    #[test]
    fn group_sync_fault_fires_after_durability() {
        let dir = TempDir::new("wal-group-fault");
        let c = faulty_durability(dir.path(), FsyncPolicy::Never, FaultPoint::AfterFsync);
        let mut j = Journal::create(&c).expect("create");
        j.append_deferred(&admit(1, 1, None)).expect("defer");
        let err = j.group_sync().expect_err("armed fault fires");
        assert!(matches!(err, JournalError::Fault("after-fsync")));
        assert!(j.is_poisoned());
        drop(j);
        let (_, recovered) = Journal::recover(&c).expect("recover");
        assert_eq!(recovered.tail.len(), 1, "record is durable but unacked");
    }
}
