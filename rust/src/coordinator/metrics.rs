//! Daemon metrics: request counters and latency histograms.

use crate::metrics::LogHistogram;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Thread-safe daemon metrics.
#[derive(Default)]
pub struct DaemonMetrics {
    /// Requests served, by outcome.
    pub requests_ok: AtomicU64,
    /// Requests that failed to parse or execute.
    pub requests_err: AtomicU64,
    /// Jobs submitted through the API.
    pub jobs_submitted: AtomicU64,
    /// Wall-clock latency of request handling (ns).
    request_latency: Mutex<LogHistogram>,
    /// *Virtual* scheduling latency of interactive jobs (recognized →
    /// dispatched, ns of sim time) — the paper's metric, live.
    sched_latency: Mutex<LogHistogram>,
}

impl DaemonMetrics {
    /// Record one request outcome + wall latency.
    pub fn record_request(&self, ok: bool, wall_ns: u64) {
        if ok {
            self.requests_ok.fetch_add(1, Ordering::Relaxed);
        } else {
            self.requests_err.fetch_add(1, Ordering::Relaxed);
        }
        self.request_latency
            .lock()
            .expect("metrics poisoned")
            .record(wall_ns);
    }

    /// Record a job's virtual scheduling latency.
    pub fn record_sched_latency(&self, sim_ns: u64) {
        self.sched_latency
            .lock()
            .expect("metrics poisoned")
            .record(sim_ns);
    }

    /// Snapshot of the request-latency histogram.
    pub fn request_latency(&self) -> LogHistogram {
        self.request_latency.lock().expect("metrics poisoned").clone()
    }

    /// Snapshot of the scheduling-latency histogram.
    pub fn sched_latency(&self) -> LogHistogram {
        self.sched_latency.lock().expect("metrics poisoned").clone()
    }

    /// One-line textual summary for the STATS command.
    pub fn summary(&self) -> String {
        format!(
            "requests_ok={} requests_err={} jobs_submitted={} | request_wall: {} | sched_virtual: {}",
            self.requests_ok.load(Ordering::Relaxed),
            self.requests_err.load(Ordering::Relaxed),
            self.jobs_submitted.load(Ordering::Relaxed),
            self.request_latency().summary_ns(),
            self.sched_latency().summary_ns(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = DaemonMetrics::default();
        m.record_request(true, 1_000_000);
        m.record_request(false, 2_000_000);
        m.record_sched_latency(500_000_000);
        m.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("requests_ok=1"));
        assert!(s.contains("requests_err=1"));
        assert!(s.contains("jobs_submitted=3"));
        assert_eq!(m.request_latency().count(), 2);
        assert_eq!(m.sched_latency().count(), 1);
    }
}
