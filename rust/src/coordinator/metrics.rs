//! Daemon metrics: request counters (total, per-command, and per lock
//! path) and latency histograms.
//!
//! The read/write-path counters make the daemon's concurrency contract
//! observable: `read_path_ops` counts requests served from the published
//! snapshot, `write_locks` counts scheduler-mutex acquisitions, and
//! `lock_hold` histograms how long each write held the mutex — a read-only
//! request that grows `write_locks` is a regression the tests assert
//! against.

use super::api::COMMANDS;
use crate::metrics::LogHistogram;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Per-reactor-shard counters. Each reactor thread owns one (registered
/// via [`DaemonMetrics::register_reactor_shard`]) and records into it *in
/// addition to* the daemon-wide roll-up counters, so the existing
/// aggregate gates (`reactor_wakeups`, zero-idle-wakeup) keep meaning
/// "across all shards" while `STATS` v2 can break the numbers out per
/// shard.
#[derive(Debug)]
pub struct ReactorShardMetrics {
    /// Shard index (registration order; shard 0 is the accept thread in
    /// single-shard mode).
    pub index: usize,
    /// `epoll_wait` returns on this shard.
    pub wakeups: AtomicU64,
    /// Readiness events delivered across this shard's wakeups.
    pub ready_events: AtomicU64,
    /// Connections this shard accepted over its lifetime.
    pub accepted: AtomicU64,
    /// Connections currently open on this shard.
    pub connections: AtomicU64,
    /// `WAIT`s currently parked on this shard's connections.
    pub parked_waits: AtomicU64,
    /// Timer-wheel entries expired on this shard.
    pub timers_fired: AtomicU64,
    /// Slow-consumer connections this shard evicted (pinned at the write
    /// backlog cap past the eviction grace deadline).
    pub evictions: AtomicU64,
}

impl ReactorShardMetrics {
    fn new(index: usize) -> Self {
        ReactorShardMetrics {
            index,
            wakeups: AtomicU64::new(0),
            ready_events: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            parked_waits: AtomicU64::new(0),
            timers_fired: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Record one `epoll_wait` return delivering `ready_events` events.
    pub fn record_wakeup(&self, ready_events: u64) {
        self.wakeups.fetch_add(1, Ordering::Relaxed);
        self.ready_events.fetch_add(ready_events, Ordering::Relaxed);
    }
}

/// Thread-safe daemon metrics.
#[derive(Default)]
pub struct DaemonMetrics {
    /// Requests served, by outcome.
    pub requests_ok: AtomicU64,
    /// Requests that failed to parse or execute.
    pub requests_err: AtomicU64,
    /// Jobs submitted through the API.
    pub jobs_submitted: AtomicU64,
    /// Requests served from the published snapshot (no scheduler lock).
    pub read_path_ops: AtomicU64,
    /// Scheduler-mutex acquisitions (mutating requests + pacing).
    pub write_locks: AtomicU64,
    /// `WAIT`s that could not complete immediately and parked.
    pub waits_parked: AtomicU64,
    /// Parked `WAIT`s that resolved (settled, timed out, or shutdown).
    /// Equal to [`DaemonMetrics::waits_parked`] once quiescent: every
    /// waiter wakes exactly once.
    pub waits_resumed: AtomicU64,
    /// Connection-reactor `epoll_wait` returns (events or timer expiry).
    /// Idle connections contribute **nothing** here — a flat counter while
    /// N connections sit open is the reactor's zero-poll guarantee, and the
    /// `connection_scaling` bench gates on it.
    pub reactor_wakeups: AtomicU64,
    /// Readiness events delivered across all reactor wakeups.
    pub reactor_ready_events: AtomicU64,
    /// Reactor threads that ever entered the serve loop for this daemon —
    /// the single-threaded-multiplexing invariant, measured (the
    /// `connection_scaling` gate asserts 1, not a constant).
    pub reactor_threads_started: AtomicU64,
    /// Virtual-time pacing passes the reactor offloaded onto the worker
    /// pool (Linux): pacing for parked `WAIT`s runs off the I/O thread, so
    /// a loaded scheduler pass can no longer stall accept/read/write for
    /// the pace duration. The in-flight guard means this also bounds
    /// concurrent paces to one.
    pub pace_offloads: AtomicU64,
    /// Journal records appended (admissions, manifests, cancels,
    /// checkpoints excluded). Zero unless the daemon runs with `--journal`.
    pub journal_appends: AtomicU64,
    /// Journal appends whose acks waited for a covering `fsync` — equal to
    /// [`DaemonMetrics::journal_appends`] under `fsync=always`, zero under
    /// `interval`/`never`. With group commit, many acks can ride one fsync;
    /// `journal_group_commits` counts the fsyncs.
    pub journal_synced_appends: AtomicU64,
    /// Group-commit leader fsyncs. `journal_synced_appends /
    /// journal_group_commits` is the realized batching factor.
    pub journal_group_commits: AtomicU64,
    /// Journal/allocator-log poison *transitions* (first I/O or fault
    /// failure per journal; later rejections of an already-poisoned journal
    /// do not count). Anything nonzero means some admissions were not
    /// acked durably.
    pub journal_poisoned: AtomicU64,
    /// `SUBMIT`s refused by the overload control plane (rate limit,
    /// inflight budget, or read-only journal).
    pub shed_submits: AtomicU64,
    /// `MSUBMIT`s (including chunked bodies) refused by the overload
    /// control plane.
    pub shed_msubmits: AtomicU64,
    /// Requests refused by a per-connection or per-user token bucket.
    pub shed_rate_limited: AtomicU64,
    /// Requests dropped because their `deadline_ms=` budget expired while
    /// queued — counted *instead of* executing, never after.
    pub deadline_expired: AtomicU64,
    /// Slow-consumer connections the reactor evicted (across all shards).
    pub conns_evicted: AtomicU64,
    /// Connections accepted by the server front door.
    pub connections_accepted: AtomicU64,
    /// `accept(2)` failures (other than would-block). The accept loop backs
    /// off exponentially on these instead of spinning at a fixed interval.
    pub accept_errors: AtomicU64,
    /// Per-command request counts, indexed like [`COMMANDS`].
    per_command: [AtomicU64; COMMANDS.len()],
    /// Wall-clock latency of request handling (ns).
    request_latency: Mutex<LogHistogram>,
    /// *Virtual* scheduling latency of interactive jobs (recognized →
    /// dispatched, ns of sim time) — the paper's metric, live.
    sched_latency: Mutex<LogHistogram>,
    /// Wall time the scheduler write mutex was held per acquisition (ns).
    lock_hold: Mutex<LogHistogram>,
    /// Wall time from `accept(2)` to the first response byte written on the
    /// connection (ns) — the front door's launch-visible latency floor.
    accept_to_first_byte: Mutex<LogHistogram>,
    /// Per-reactor-shard counters, registration order. Empty until a
    /// server binds; one entry per reactor thread after that.
    reactor_shards: Mutex<Vec<Arc<ReactorShardMetrics>>>,
}

impl DaemonMetrics {
    /// Record one request outcome + wall latency.
    pub fn record_request(&self, ok: bool, wall_ns: u64) {
        if ok {
            self.requests_ok.fetch_add(1, Ordering::Relaxed);
        } else {
            self.requests_err.fetch_add(1, Ordering::Relaxed);
        }
        self.request_latency
            .lock()
            .expect("metrics poisoned")
            .record(wall_ns);
    }

    /// Count one parsed request by its command verb (a [`COMMANDS`] entry).
    pub fn record_command(&self, command: &str) {
        if let Some(i) = COMMANDS.iter().position(|&c| c == command) {
            self.per_command[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Snapshot of the per-command counters, in [`COMMANDS`] order.
    pub fn command_counts(&self) -> Vec<(&'static str, u64)> {
        COMMANDS
            .iter()
            .zip(&self.per_command)
            .map(|(&cmd, n)| (cmd, n.load(Ordering::Relaxed)))
            .collect()
    }

    /// Count one snapshot-served (lock-free) request.
    pub fn record_read_path(&self) {
        self.read_path_ops.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one scheduler-mutex acquisition and its hold time.
    pub fn record_write_lock(&self, hold_ns: u64) {
        self.write_locks.fetch_add(1, Ordering::Relaxed);
        self.lock_hold
            .lock()
            .expect("metrics poisoned")
            .record(hold_ns);
    }

    /// Snapshot of the write-lock hold-time histogram.
    pub fn lock_hold(&self) -> LogHistogram {
        self.lock_hold.lock().expect("metrics poisoned").clone()
    }

    /// Record one reactor wakeup delivering `ready_events` events.
    pub fn record_reactor_wakeup(&self, ready_events: u64) {
        self.reactor_wakeups.fetch_add(1, Ordering::Relaxed);
        self.reactor_ready_events
            .fetch_add(ready_events, Ordering::Relaxed);
    }

    /// Register one reactor shard's counter block. Returns the shard's
    /// handle; the index is the registration order.
    pub fn register_reactor_shard(&self) -> Arc<ReactorShardMetrics> {
        let mut shards = self.reactor_shards.lock().expect("metrics poisoned");
        let m = Arc::new(ReactorShardMetrics::new(shards.len()));
        shards.push(Arc::clone(&m));
        m
    }

    /// Handles of every registered reactor shard, index order.
    pub fn reactor_shards(&self) -> Vec<Arc<ReactorShardMetrics>> {
        self.reactor_shards
            .lock()
            .expect("metrics poisoned")
            .clone()
    }

    /// Record a connection's accept-to-first-response-byte latency.
    pub fn record_accept_to_first_byte(&self, wall_ns: u64) {
        self.accept_to_first_byte
            .lock()
            .expect("metrics poisoned")
            .record(wall_ns);
    }

    /// Snapshot of the accept-to-first-byte histogram.
    pub fn accept_to_first_byte(&self) -> LogHistogram {
        self.accept_to_first_byte
            .lock()
            .expect("metrics poisoned")
            .clone()
    }

    /// Record a job's virtual scheduling latency.
    pub fn record_sched_latency(&self, sim_ns: u64) {
        self.sched_latency
            .lock()
            .expect("metrics poisoned")
            .record(sim_ns);
    }

    /// Snapshot of the request-latency histogram.
    pub fn request_latency(&self) -> LogHistogram {
        self.request_latency.lock().expect("metrics poisoned").clone()
    }

    /// Snapshot of the scheduling-latency histogram.
    pub fn sched_latency(&self) -> LogHistogram {
        self.sched_latency.lock().expect("metrics poisoned").clone()
    }

    /// One-line textual summary (e2e reporting).
    pub fn summary(&self) -> String {
        format!(
            "requests_ok={} requests_err={} jobs_submitted={} read_path={} write_locks={} \
             waits={}/{} conns={} accept_errs={} reactor_wakeups={} reactor_events={} \
             pace_offloads={} journal={}/{}s/{}gc/{}poisoned \
             shed={}sub/{}msub/{}rate/{}deadline/{}evicted \
             | request_wall: {} | sched_virtual: {} | lock_hold: {} | accept_to_first_byte: {}",
            self.requests_ok.load(Ordering::Relaxed),
            self.requests_err.load(Ordering::Relaxed),
            self.jobs_submitted.load(Ordering::Relaxed),
            self.read_path_ops.load(Ordering::Relaxed),
            self.write_locks.load(Ordering::Relaxed),
            self.waits_resumed.load(Ordering::Relaxed),
            self.waits_parked.load(Ordering::Relaxed),
            self.connections_accepted.load(Ordering::Relaxed),
            self.accept_errors.load(Ordering::Relaxed),
            self.reactor_wakeups.load(Ordering::Relaxed),
            self.reactor_ready_events.load(Ordering::Relaxed),
            self.pace_offloads.load(Ordering::Relaxed),
            self.journal_appends.load(Ordering::Relaxed),
            self.journal_synced_appends.load(Ordering::Relaxed),
            self.journal_group_commits.load(Ordering::Relaxed),
            self.journal_poisoned.load(Ordering::Relaxed),
            self.shed_submits.load(Ordering::Relaxed),
            self.shed_msubmits.load(Ordering::Relaxed),
            self.shed_rate_limited.load(Ordering::Relaxed),
            self.deadline_expired.load(Ordering::Relaxed),
            self.conns_evicted.load(Ordering::Relaxed),
            self.request_latency().summary_ns(),
            self.sched_latency().summary_ns(),
            self.lock_hold().summary_ns(),
            self.accept_to_first_byte().summary_ns(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = DaemonMetrics::default();
        m.record_request(true, 1_000_000);
        m.record_request(false, 2_000_000);
        m.record_sched_latency(500_000_000);
        m.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("requests_ok=1"));
        assert!(s.contains("requests_err=1"));
        assert!(s.contains("jobs_submitted=3"));
        assert_eq!(m.request_latency().count(), 2);
        assert_eq!(m.sched_latency().count(), 1);
    }

    #[test]
    fn lock_path_counters() {
        let m = DaemonMetrics::default();
        m.record_read_path();
        m.record_read_path();
        m.record_write_lock(5_000);
        assert_eq!(m.read_path_ops.load(Ordering::Relaxed), 2);
        assert_eq!(m.write_locks.load(Ordering::Relaxed), 1);
        assert_eq!(m.lock_hold().count(), 1);
        assert!(m.summary().contains("read_path=2"));
        assert!(m.summary().contains("write_locks=1"));
    }

    #[test]
    fn reactor_counters_accumulate() {
        let m = DaemonMetrics::default();
        m.record_reactor_wakeup(3);
        m.record_reactor_wakeup(0);
        m.record_accept_to_first_byte(250_000);
        assert_eq!(m.reactor_wakeups.load(Ordering::Relaxed), 2);
        assert_eq!(m.reactor_ready_events.load(Ordering::Relaxed), 3);
        assert_eq!(m.accept_to_first_byte().count(), 1);
        assert!(m.summary().contains("reactor_wakeups=2"));
    }

    #[test]
    fn reactor_shard_registry_indexes_and_counts() {
        let m = DaemonMetrics::default();
        assert!(m.reactor_shards().is_empty());
        let a = m.register_reactor_shard();
        let b = m.register_reactor_shard();
        assert_eq!((a.index, b.index), (0, 1));
        a.record_wakeup(2);
        a.record_wakeup(0);
        b.record_wakeup(5);
        let shards = m.reactor_shards();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].wakeups.load(Ordering::Relaxed), 2);
        assert_eq!(shards[0].ready_events.load(Ordering::Relaxed), 2);
        assert_eq!(shards[1].wakeups.load(Ordering::Relaxed), 1);
        assert_eq!(shards[1].ready_events.load(Ordering::Relaxed), 5);
        // The registry hands out the same blocks it aggregates.
        assert!(Arc::ptr_eq(&a, &shards[0]));
    }

    #[test]
    fn per_command_counts() {
        let m = DaemonMetrics::default();
        m.record_command("SUBMIT");
        m.record_command("SUBMIT");
        m.record_command("WAIT");
        m.record_command("NO_SUCH_COMMAND"); // silently ignored
        let counts: std::collections::BTreeMap<&str, u64> =
            m.command_counts().into_iter().collect();
        assert_eq!(counts["SUBMIT"], 2);
        assert_eq!(counts["WAIT"], 1);
        assert_eq!(counts["PING"], 0);
    }
}
