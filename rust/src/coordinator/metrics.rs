//! Daemon metrics: request counters (total and per-command) and latency
//! histograms.

use super::api::COMMANDS;
use crate::metrics::LogHistogram;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Thread-safe daemon metrics.
#[derive(Default)]
pub struct DaemonMetrics {
    /// Requests served, by outcome.
    pub requests_ok: AtomicU64,
    /// Requests that failed to parse or execute.
    pub requests_err: AtomicU64,
    /// Jobs submitted through the API.
    pub jobs_submitted: AtomicU64,
    /// Per-command request counts, indexed like [`COMMANDS`].
    per_command: [AtomicU64; COMMANDS.len()],
    /// Wall-clock latency of request handling (ns).
    request_latency: Mutex<LogHistogram>,
    /// *Virtual* scheduling latency of interactive jobs (recognized →
    /// dispatched, ns of sim time) — the paper's metric, live.
    sched_latency: Mutex<LogHistogram>,
}

impl DaemonMetrics {
    /// Record one request outcome + wall latency.
    pub fn record_request(&self, ok: bool, wall_ns: u64) {
        if ok {
            self.requests_ok.fetch_add(1, Ordering::Relaxed);
        } else {
            self.requests_err.fetch_add(1, Ordering::Relaxed);
        }
        self.request_latency
            .lock()
            .expect("metrics poisoned")
            .record(wall_ns);
    }

    /// Count one parsed request by its command verb (a [`COMMANDS`] entry).
    pub fn record_command(&self, command: &str) {
        if let Some(i) = COMMANDS.iter().position(|&c| c == command) {
            self.per_command[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Snapshot of the per-command counters, in [`COMMANDS`] order.
    pub fn command_counts(&self) -> Vec<(&'static str, u64)> {
        COMMANDS
            .iter()
            .zip(&self.per_command)
            .map(|(&cmd, n)| (cmd, n.load(Ordering::Relaxed)))
            .collect()
    }

    /// Record a job's virtual scheduling latency.
    pub fn record_sched_latency(&self, sim_ns: u64) {
        self.sched_latency
            .lock()
            .expect("metrics poisoned")
            .record(sim_ns);
    }

    /// Snapshot of the request-latency histogram.
    pub fn request_latency(&self) -> LogHistogram {
        self.request_latency.lock().expect("metrics poisoned").clone()
    }

    /// Snapshot of the scheduling-latency histogram.
    pub fn sched_latency(&self) -> LogHistogram {
        self.sched_latency.lock().expect("metrics poisoned").clone()
    }

    /// One-line textual summary (e2e reporting).
    pub fn summary(&self) -> String {
        format!(
            "requests_ok={} requests_err={} jobs_submitted={} | request_wall: {} | sched_virtual: {}",
            self.requests_ok.load(Ordering::Relaxed),
            self.requests_err.load(Ordering::Relaxed),
            self.jobs_submitted.load(Ordering::Relaxed),
            self.request_latency().summary_ns(),
            self.sched_latency().summary_ns(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = DaemonMetrics::default();
        m.record_request(true, 1_000_000);
        m.record_request(false, 2_000_000);
        m.record_sched_latency(500_000_000);
        m.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("requests_ok=1"));
        assert!(s.contains("requests_err=1"));
        assert!(s.contains("jobs_submitted=3"));
        assert_eq!(m.request_latency().count(), 2);
        assert_eq!(m.sched_latency().count(), 1);
    }

    #[test]
    fn per_command_counts() {
        let m = DaemonMetrics::default();
        m.record_command("SUBMIT");
        m.record_command("SUBMIT");
        m.record_command("WAIT");
        m.record_command("NO_SUCH_COMMAND"); // silently ignored
        let counts: std::collections::BTreeMap<&str, u64> =
            m.command_counts().into_iter().collect();
        assert_eq!(counts["SUBMIT"], 2);
        assert_eq!(counts["WAIT"], 1);
        assert_eq!(counts["PING"], 0);
    }
}
