//! The runnable coordinator daemon.
//!
//! Wraps the [`crate::sched::Scheduler`] in a thread-safe service with a
//! versioned, typed TCP API (tokio is unavailable offline, so the
//! connection handling runs on our own [`threadpool`]):
//!
//! * [`api`] — the typed protocol core: `Request` / `Response` enums,
//!   payload structs (`SubmitAck`, `JobSummary`, `StatsSnapshot`, …), and
//!   typed `ErrorCode`s.
//! * [`codec`] — wire rendering/parsing for both protocol versions: v1 (the
//!   original line grammar, byte-compatible) and v2 (tagged `key=value`
//!   records), negotiated per connection via `HELLO v2`. See `PROTOCOL.md`.
//! * [`daemon`] — the service core: a **write path** (SUBMIT/SCANCEL/
//!   pacing) behind the scheduler mutex that publishes an immutable
//!   [`snapshot::SchedSnapshot`] after every mutation, and a **read path**
//!   (SQUEUE/SJOB/STATS/UTIL) served from the published snapshot without
//!   the scheduler lock; batched `SUBMIT`; subscription-based `WAIT`;
//!   per-request and per-lock-path metrics.
//! * [`snapshot`] — the published read view and the `WAIT` completion hub
//!   (condvar keyed by a dispatch/terminal generation).
//! * [`server`] — TCP listener + connection loop (per-connection protocol
//!   version, idle-connection expiry, parked-`WAIT` registry so blocked
//!   waits never pin pool workers).
//! * [`client`] — the blocking typed client for the CLI, examples, and
//!   tests.
//! * [`metrics`] — daemon counters (total, per-command, per lock path) and
//!   latency histograms.
//! * [`threadpool`] — fixed worker pool substrate.

pub mod api;
pub mod client;
pub mod codec;
pub mod daemon;
pub mod metrics;
pub mod server;
pub mod snapshot;
pub mod threadpool;

pub use api::{
    ApiError, ContentionStats, ErrorCode, JobDetail, JobSummary, ProtocolVersion, Request,
    Response, SqueueFilter, StatsSnapshot, SubmitAck, SubmitSpec, UtilSnapshot, WaitResult,
};
pub use client::{Client, ClientError};
pub use daemon::{Daemon, DaemonConfig};
pub use server::Server;
pub use snapshot::{JobView, SchedSnapshot, WaitHub};
