//! The runnable coordinator daemon.
//!
//! Wraps the [`crate::sched::Scheduler`] in a thread-safe service with a
//! versioned, typed TCP API (tokio is unavailable offline, so the
//! connection handling runs on our own [`threadpool`]):
//!
//! * [`api`] — the typed protocol core: `Request` / `Response` enums,
//!   payload structs (`SubmitAck`, `JobSummary`, `StatsSnapshot`, …), and
//!   typed `ErrorCode`s.
//! * [`codec`] — wire rendering/parsing for every protocol dialect: v1 (the
//!   original line grammar, byte-compatible), v2/v2.1 (tagged `key=value`
//!   records, chunked manifests), and v3 (length-prefixed binary frames
//!   with varint-packed manifest records), negotiated per connection via
//!   `HELLO`. See `PROTOCOL.md`.
//! * [`manifest`] — typed submission manifests (`MSUBMIT`): heterogeneous
//!   per-entry job specs in one RPC, partial-accept admission with typed
//!   per-entry rejects, and the client-side `ManifestBuilder`.
//! * [`daemon`] — the service core: a **write path** (SUBMIT/SCANCEL/
//!   pacing) behind the scheduler mutex that publishes an immutable
//!   [`snapshot::SchedSnapshot`] after every mutation, and a **read path**
//!   (SQUEUE/SJOB/STATS/UTIL) served from the published snapshot without
//!   the scheduler lock; batched `SUBMIT`; subscription-based `WAIT`;
//!   per-request and per-lock-path metrics.
//! * [`snapshot`] — the published read view and the `WAIT` completion hub
//!   (condvar keyed by a dispatch/terminal generation).
//! * [`shards`] — the partition-sharded scheduler back end: per-partition
//!   scheduler shards (own mutex, queues, snapshot delta) over disjoint
//!   node slices, one global id allocator, and an epoch/merge protocol on
//!   the publish path so readers still see one coherent snapshot
//!   (`shard_count = 1` is exactly the unsharded daemon).
//! * [`server`] — the TCP front door. On Linux it is an `epoll` readiness
//!   **reactor** ([`reactor`], std-only syscall bindings): every socket is
//!   nonblocking, idle connections cost no thread and no poll tick, accept
//!   is edge-driven, and parked `WAIT`s wake the reactor through an
//!   eventfd subscribed to the completion hub. Non-Linux targets keep the
//!   portable threadpool connection loop (per-connection protocol version,
//!   idle expiry, parked-`WAIT` registry).
//! * [`timerwheel`] — hashed timer wheel for the reactor's idle and
//!   `WAIT`-deadline tracking (O(1) insert, amortized O(1) expiry).
//! * [`journal`] — the durability write-ahead log: length-prefixed
//!   checksummed records in rotating segments, appended (and fsync'd per
//!   the configured policy) *before* a submission is acked, bounded by
//!   checkpoint-truncation. Shard-aware: each scheduler shard owns a
//!   journal under `shard-<i>/`, id-range leases go through the allocator
//!   log (`alloc.log`), and `fsync=always` acks ride group commits.
//! * [`recovery`] — crash recovery: replay the newest checkpoint plus the
//!   journal tail into a fresh scheduler (per shard in sharded layouts,
//!   reconciling cross-shard manifests via lease completeness), with a
//!   typed `RecoveryReport`.
//! * [`client`] — the blocking typed client for the CLI, examples, and
//!   tests (round trips and pipelined batches); `RESUME`-based re-attach
//!   with retry/backoff.
//! * [`metrics`] — daemon counters (total, per-command, per lock path,
//!   reactor wakeups/ready-events) and latency histograms.
//! * [`threadpool`] — fixed worker pool substrate (request execution under
//!   the reactor; whole-connection driving on non-Linux).

pub mod api;
pub mod client;
pub mod codec;
pub mod daemon;
pub mod journal;
pub mod manifest;
pub mod metrics;
pub mod recovery;
#[cfg(target_os = "linux")]
pub(crate) mod reactor;
pub mod server;
pub mod shards;
pub mod snapshot;
pub mod threadpool;
pub mod timerwheel;

pub use api::{
    ApiError, ContentionStats, ErrorCode, HealthReport, HealthState, JobDetail, JobSummary,
    JournalStats, ProtocolVersion, Request, Response, ResumeEntry, ResumeInfo, ResumeTarget,
    ShardKind, ShardStats, ShardUtil, SqueueFilter, StatsSnapshot, SubmitAck, SubmitSpec,
    UserScaleStats, UtilSnapshot, WaitResult,
};
pub use client::{Client, ClientError, RetryPolicy};
pub use daemon::{ConfigError, Daemon, DaemonConfig, OverloadConfig, TokenBucket};
pub use journal::{
    AllocLease, AllocLog, DurabilityConfig, FaultPlan, FaultPoint, FsyncPolicy, Journal,
    JournalError,
};
pub use manifest::{
    ChunkAssembler, ChunkOutcome, EntryAck, EntryReject, Manifest, ManifestAck,
    ManifestBuilder, ManifestChunk, ManifestEntry, ManifestRegistry, ManifestSpan,
    RegisteredManifest,
};
pub use recovery::{RecoveryError, RecoveryReport};
pub use server::Server;
pub use shards::{SchedShardStat, SchedShards};
pub use snapshot::{JobView, SchedSnapshot, WaitHub};
