//! The runnable coordinator daemon.
//!
//! Wraps the [`crate::sched::Scheduler`] in a thread-safe service with a
//! versioned, typed TCP API (tokio is unavailable offline, so the
//! connection handling runs on our own [`threadpool`]):
//!
//! * [`api`] — the typed protocol core: `Request` / `Response` enums,
//!   payload structs (`SubmitAck`, `JobSummary`, `StatsSnapshot`, …), and
//!   typed `ErrorCode`s.
//! * [`codec`] — wire rendering/parsing for both protocol versions: v1 (the
//!   original line grammar, byte-compatible) and v2 (tagged `key=value`
//!   records), negotiated per connection via `HELLO v2`. See `PROTOCOL.md`.
//! * [`daemon`] — the service core: scheduler behind a mutex, a pacer thread
//!   that advances virtual time against the wall clock at a configurable
//!   speedup, batched `SUBMIT`, blocking `WAIT`, and per-request metrics.
//! * [`server`] — TCP listener + connection loop (per-connection protocol
//!   version, idle-connection expiry).
//! * [`client`] — the blocking typed client for the CLI, examples, and
//!   tests.
//! * [`metrics`] — daemon counters (total and per-command) and latency
//!   histograms.
//! * [`threadpool`] — fixed worker pool substrate.

pub mod api;
pub mod client;
pub mod codec;
pub mod daemon;
pub mod metrics;
pub mod server;
pub mod threadpool;

pub use api::{
    ApiError, ErrorCode, JobDetail, JobSummary, ProtocolVersion, Request, Response, SqueueFilter,
    StatsSnapshot, SubmitAck, SubmitSpec, UtilSnapshot, WaitResult,
};
pub use client::{Client, ClientError};
pub use daemon::{Daemon, DaemonConfig};
pub use server::Server;
