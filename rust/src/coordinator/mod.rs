//! The runnable coordinator daemon.
//!
//! Wraps the [`crate::sched::Scheduler`] in a thread-safe service with a
//! line-based TCP API (tokio is unavailable offline, so the connection
//! handling runs on our own [`threadpool`]):
//!
//! * [`daemon`] — the service core: scheduler behind a mutex, a pacer thread
//!   that advances virtual time against the wall clock at a configurable
//!   speedup, and per-request latency metrics.
//! * [`api`] — the text protocol (SUBMIT/SQUEUE/SCANCEL/STATS/...).
//! * [`server`] — TCP listener + connection loop.
//! * [`client`] — a blocking client for the CLI and examples.
//! * [`metrics`] — daemon counters and latency histograms.
//! * [`threadpool`] — fixed worker pool substrate.

pub mod api;
pub mod client;
pub mod daemon;
pub mod metrics;
pub mod server;
pub mod threadpool;

pub use daemon::{Daemon, DaemonConfig};
pub use server::Server;
