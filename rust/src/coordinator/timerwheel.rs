//! A hashed timer wheel for the connection reactor.
//!
//! The reactor tracks one idle deadline per connection plus one deadline per
//! parked `WAIT`; with thousands of connections a sorted structure would pay
//! O(log n) per re-arm and the naive "scan everything each tick" is exactly
//! the per-connection poll cost the reactor exists to remove. The wheel
//! gives O(1) insertion and amortized O(1) expiry: a deadline hashes into
//! one of `slots` buckets of width `granularity`; [`TimerWheel::expire`]
//! drains only the buckets the clock actually crossed. Deadlines further
//! out than one revolution stay in their bucket and are re-examined once
//! per revolution (cheap: a comparison), which keeps the structure a single
//! level instead of a hierarchy.
//!
//! Entries are never removed early. The reactor uses **lazy invalidation**:
//! each entry carries a token + generation, and a fired entry whose
//! connection has moved on (new deadline, closed slot, reused slot) is
//! simply dropped or re-inserted by the expiry callback. That makes re-arm
//! (the per-request hot path) allocation- and search-free.
//!
//! The wheel is deliberately single-threaded: under a sharded front door
//! ([`super::server::Server::bind_sharded`]) each reactor shard owns its
//! own wheel for its own connections, so timer state needs no locking and
//! shard counts scale the timer load linearly.

use std::time::{Duration, Instant};

/// A single-level hashed timer wheel. `T` is the caller's timer payload.
pub struct TimerWheel<T> {
    /// All deadlines are stored as whole milliseconds since this origin so
    /// bucket math is integral.
    origin: Instant,
    /// Bucket width in milliseconds.
    gran_ms: u64,
    /// `slots[tick % slots.len()]` holds `(deadline_ms, item)` pairs.
    slots: Vec<Vec<(u64, T)>>,
    /// Smallest deadline per bucket (`u64::MAX` when empty): lets a sweep
    /// refresh the global minimum in O(buckets) instead of O(entries) — at
    /// thousands of idle-connection deadlines, an O(entries) rescan per
    /// fired timer would put a per-idle-connection cost on the reactor.
    bucket_min: Vec<u64>,
    /// Every tick strictly below `cursor` has been drained of due entries.
    cursor: u64,
    /// Live entries across all buckets.
    len: usize,
    /// Smallest deadline among live entries (`u64::MAX` when empty);
    /// maintained on insert, refreshed from `bucket_min` after a sweep
    /// that removed entries.
    earliest_ms: u64,
}

impl<T> TimerWheel<T> {
    /// A wheel of `slots` buckets of `granularity` each. The horizon
    /// (`slots × granularity`) only bounds how often a far-future entry is
    /// re-examined, not how far out a deadline may be.
    pub fn new(granularity: Duration, slots: usize) -> Self {
        assert!(slots > 0, "wheel needs at least one slot");
        let gran_ms = granularity.as_millis().max(1) as u64;
        Self {
            origin: Instant::now(),
            gran_ms,
            slots: (0..slots).map(|_| Vec::new()).collect(),
            bucket_min: vec![u64::MAX; slots],
            cursor: 0,
            len: 0,
            earliest_ms: u64::MAX,
        }
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.len
    }

    /// No live entries?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn ms_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.origin).as_millis() as u64
    }

    /// Schedule `item` at `deadline`. Deadlines in the past fire on the next
    /// [`TimerWheel::expire`] call.
    pub fn insert(&mut self, deadline: Instant, item: T) {
        let ms = self.ms_of(deadline);
        // A deadline the cursor already passed would land in a drained
        // bucket and wait a whole revolution; pin it to the cursor tick so
        // the next sweep sees it.
        let tick = (ms / self.gran_ms).max(self.cursor);
        let slot = (tick % self.slots.len() as u64) as usize;
        self.slots[slot].push((ms, item));
        self.bucket_min[slot] = self.bucket_min[slot].min(ms);
        self.len += 1;
        self.earliest_ms = self.earliest_ms.min(ms);
    }

    /// The earliest pending deadline (what the reactor sleeps until).
    pub fn next_deadline(&self) -> Option<Instant> {
        if self.len == 0 {
            None
        } else {
            Some(self.origin + Duration::from_millis(self.earliest_ms))
        }
    }

    /// Pop every entry whose deadline is at or before `now` into `f`,
    /// advancing the cursor. Entries hashed into a crossed bucket but due
    /// in a later revolution are kept in place.
    pub fn expire(&mut self, now: Instant, mut f: impl FnMut(T)) {
        let now_ms = self.ms_of(now);
        let now_tick = now_ms / self.gran_ms;
        if self.len == 0 {
            self.cursor = now_tick;
            return;
        }
        if now_tick < self.cursor {
            return; // clock has not crossed into an undrained tick yet
        }
        let nslots = self.slots.len() as u64;
        // One full revolution visits every bucket, so cap the walk there:
        // after it, anything still stored is due in the future.
        let last = now_tick.min(self.cursor + nslots - 1);
        let mut tick = self.cursor;
        let mut fired_any = false;
        while tick <= last {
            let slot = (tick % nslots) as usize;
            let bucket = &mut self.slots[slot];
            let mut i = 0;
            let mut kept_min = u64::MAX;
            while i < bucket.len() {
                if bucket[i].0 <= now_ms {
                    let (_, item) = bucket.swap_remove(i);
                    self.len -= 1;
                    fired_any = true;
                    f(item);
                } else {
                    kept_min = kept_min.min(bucket[i].0);
                    i += 1;
                }
            }
            // We saw every kept entry, so this is the bucket's exact min.
            self.bucket_min[slot] = kept_min;
            tick += 1;
        }
        // The bucket for `now_tick` may still hold entries due later within
        // this same tick — leave the cursor *on* it so they are re-checked.
        self.cursor = now_tick;
        // Refresh the cached global minimum only when an entry actually
        // left the wheel (it can only shrink on insert, only grow via
        // removal), and from the per-bucket minima — O(buckets), never
        // O(entries), so a fired timer does not pay for every idle
        // connection's far-out deadline.
        if fired_any {
            self.earliest_ms = if self.len == 0 {
                u64::MAX
            } else {
                self.bucket_min.iter().copied().min().unwrap_or(u64::MAX)
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wheel() -> TimerWheel<u32> {
        TimerWheel::new(Duration::from_millis(10), 16)
    }

    #[test]
    fn fires_due_entries_in_any_order() {
        let mut w = wheel();
        let now = Instant::now();
        w.insert(now + Duration::from_millis(5), 1);
        w.insert(now + Duration::from_millis(25), 2);
        w.insert(now + Duration::from_millis(500), 3);
        assert_eq!(w.len(), 3);
        let mut fired = Vec::new();
        w.expire(now + Duration::from_millis(30), |x| fired.push(x));
        fired.sort_unstable();
        assert_eq!(fired, vec![1, 2]);
        assert_eq!(w.len(), 1);
        // The far entry fires once the clock reaches it.
        let mut fired = Vec::new();
        w.expire(now + Duration::from_millis(600), |x| fired.push(x));
        assert_eq!(fired, vec![3]);
        assert!(w.is_empty());
    }

    #[test]
    fn past_deadlines_fire_on_next_expire() {
        let mut w = wheel();
        let now = Instant::now();
        w.expire(now + Duration::from_millis(200), |_| {});
        // Insert behind the cursor: must still fire promptly.
        w.insert(now, 7);
        let mut fired = Vec::new();
        w.expire(now + Duration::from_millis(201), |x| fired.push(x));
        assert_eq!(fired, vec![7]);
    }

    #[test]
    fn beyond_horizon_entries_survive_revolutions() {
        let mut w = TimerWheel::new(Duration::from_millis(10), 4); // 40ms horizon
        let now = Instant::now();
        w.insert(now + Duration::from_millis(95), 9);
        // Sweep several times inside the horizon: nothing fires, and the
        // cached minimum survives the no-op sweeps.
        for step in [10u64, 30, 60, 90] {
            let mut fired = Vec::new();
            w.expire(now + Duration::from_millis(step), |x| fired.push(x));
            assert!(fired.is_empty(), "fired early at +{step}ms");
            assert!(w.next_deadline().is_some(), "min lost by a no-op sweep");
        }
        let mut fired = Vec::new();
        w.expire(now + Duration::from_millis(120), |x| fired.push(x));
        assert_eq!(fired, vec![9]);
    }

    #[test]
    fn next_deadline_tracks_minimum() {
        let mut w = wheel();
        assert!(w.next_deadline().is_none());
        let now = Instant::now();
        w.insert(now + Duration::from_millis(80), 1);
        w.insert(now + Duration::from_millis(20), 2);
        let nd = w.next_deadline().unwrap();
        assert!(nd <= now + Duration::from_millis(21), "min not tracked");
        w.expire(now + Duration::from_millis(40), |_| {});
        let nd = w.next_deadline().unwrap();
        assert!(nd >= now + Duration::from_millis(70), "min not recomputed");
    }

    #[test]
    fn same_tick_later_entry_is_rechecked() {
        // An entry due in the same wheel tick as `now` but a few ms later
        // must not be skipped when the cursor lands on its bucket.
        let mut w = TimerWheel::new(Duration::from_millis(100), 8);
        let now = Instant::now();
        w.insert(now + Duration::from_millis(60), 1);
        let mut fired = Vec::new();
        w.expire(now + Duration::from_millis(10), |x| fired.push(x));
        assert!(fired.is_empty());
        w.expire(now + Duration::from_millis(70), |x| fired.push(x));
        assert_eq!(fired, vec![1]);
    }

    #[test]
    fn large_population_drains_fully() {
        let mut w = TimerWheel::new(Duration::from_millis(5), 32);
        let now = Instant::now();
        for i in 0..1000u32 {
            w.insert(now + Duration::from_millis(u64::from(i % 200)), i);
        }
        let mut fired = 0usize;
        w.expire(now + Duration::from_millis(300), |_| fired += 1);
        assert_eq!(fired, 1000);
        assert!(w.is_empty());
        assert!(w.next_deadline().is_none());
    }
}
