//! Job substrate: specs, lifecycle state machine, QoS, per-user accounting.

pub mod qos;
pub mod spec;
pub mod user;

pub use qos::{QosClass, QosConfig, QosTable};
pub use spec::{JobSpec, JobType};
pub use user::{UserAccounting, UserId, UserLimits};

use crate::cluster::AllocRequest;
use crate::sim::SimTime;

/// Job identifier (monotonically assigned by the scheduler).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job#{}", self.0)
    }
}

/// Job lifecycle states (subset of Slurm's with the preemption states the
/// paper exercises).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobState {
    /// In the pending queue, not yet allocated.
    Pending,
    /// Dispatched and running.
    Running,
    /// Ran to completion.
    Completed,
    /// Preempted with REQUEUE: back in the pending queue (keeps a new
    /// submit time for LIFO ordering purposes the paper relies on).
    Requeued,
    /// Preempted with CANCEL (or user scancel): terminal.
    Cancelled,
    /// Preempted with SUSPEND: frozen in memory on its nodes.
    Suspended,
}

impl JobState {
    /// Terminal states never transition again.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Completed | JobState::Cancelled)
    }

    /// States in which the job occupies (at least memory on) its nodes.
    pub fn holds_resources(self) -> bool {
        matches!(self, JobState::Running | JobState::Suspended)
    }

    /// Whether `self -> next` is a legal transition.
    pub fn can_transition_to(self, next: JobState) -> bool {
        use JobState::*;
        matches!(
            (self, next),
            (Pending, Running)
                | (Pending, Cancelled)
                | (Running, Completed)
                | (Running, Requeued)
                | (Running, Cancelled)
                | (Running, Suspended)
                | (Suspended, Running)
                | (Suspended, Cancelled)
                | (Requeued, Pending)
                | (Requeued, Cancelled)
        )
    }
}

/// A job record owned by the scheduler.
#[derive(Debug, Clone)]
pub struct Job {
    /// Identifier.
    pub id: JobId,
    /// Immutable submission spec.
    pub spec: JobSpec,
    /// Current lifecycle state.
    pub state: JobState,
    /// Original submission time.
    pub submit_time: SimTime,
    /// Time the job (re-)entered the pending queue — requeue resets this,
    /// which is what makes "preempt youngest first" LIFO meaningful.
    pub queue_time: SimTime,
    /// Time the job started running (last start for requeued jobs).
    pub start_time: Option<SimTime>,
    /// Time the job reached a terminal state.
    pub end_time: Option<SimTime>,
    /// How many times this job has been preempted+requeued.
    pub requeue_count: u32,
    /// Monotone per-job change counter: bumped on every externally visible
    /// mutation (state transitions; the scheduler also bumps it when a log
    /// record changes a derived field, e.g. `Recognized`). Snapshot capture
    /// keys its per-job delta reuse on this.
    revision: u64,
}

impl Job {
    /// Create a pending job record.
    pub fn new(id: JobId, spec: JobSpec, now: SimTime) -> Self {
        Self {
            id,
            spec,
            state: JobState::Pending,
            submit_time: now,
            queue_time: now,
            start_time: None,
            end_time: None,
            requeue_count: 0,
            revision: 0,
        }
    }

    /// Per-job change counter (see the field doc). Equal revisions for the
    /// same job id guarantee an identical externally visible record.
    pub fn revision(&self) -> u64 {
        self.revision
    }

    /// Bump the change counter for a mutation that does not go through
    /// [`Job::transition`] (scheduler-internal; e.g. the `Recognized` log
    /// record materializing the job's recognized time).
    pub(crate) fn touch(&mut self) {
        self.revision += 1;
    }

    /// Validated state transition. Panics on an illegal transition — these
    /// indicate scheduler bugs and must fail loudly in simulation.
    pub fn transition(&mut self, next: JobState, now: SimTime) {
        assert!(
            self.state.can_transition_to(next),
            "{}: illegal transition {:?} -> {:?}",
            self.id,
            self.state,
            next
        );
        self.revision += 1;
        match next {
            JobState::Running => self.start_time = Some(now),
            JobState::Completed | JobState::Cancelled => self.end_time = Some(now),
            JobState::Requeued => self.requeue_count += 1,
            JobState::Pending => self.queue_time = now,
            JobState::Suspended => {}
        }
        self.state = next;
    }

    /// The allocation request this job makes.
    pub fn alloc_request(&self, cores_per_node: u32) -> AllocRequest {
        self.spec.alloc_request(cores_per_node)
    }

    /// True for spot (preemptable) jobs.
    pub fn is_spot(&self) -> bool {
        self.spec.qos == QosClass::Spot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec::interactive(UserId(1), JobType::Array, 64)
    }

    #[test]
    fn lifecycle_happy_path() {
        let mut j = Job::new(JobId(1), spec(), SimTime::ZERO);
        assert_eq!(j.state, JobState::Pending);
        j.transition(JobState::Running, SimTime::from_secs(1));
        assert_eq!(j.start_time, Some(SimTime::from_secs(1)));
        j.transition(JobState::Completed, SimTime::from_secs(10));
        assert_eq!(j.end_time, Some(SimTime::from_secs(10)));
        assert!(j.state.is_terminal());
    }

    #[test]
    fn requeue_cycle_updates_queue_time_and_count() {
        let mut j = Job::new(JobId(1), spec(), SimTime::ZERO);
        j.transition(JobState::Running, SimTime::from_secs(1));
        j.transition(JobState::Requeued, SimTime::from_secs(5));
        assert_eq!(j.requeue_count, 1);
        j.transition(JobState::Pending, SimTime::from_secs(6));
        assert_eq!(j.queue_time, SimTime::from_secs(6));
        assert_eq!(j.submit_time, SimTime::ZERO, "submit time is immutable");
        j.transition(JobState::Running, SimTime::from_secs(7));
        assert_eq!(j.start_time, Some(SimTime::from_secs(7)));
    }

    #[test]
    fn suspend_resume() {
        let mut j = Job::new(JobId(1), spec(), SimTime::ZERO);
        j.transition(JobState::Running, SimTime::from_secs(1));
        j.transition(JobState::Suspended, SimTime::from_secs(2));
        assert!(j.state.holds_resources());
        j.transition(JobState::Running, SimTime::from_secs(3));
        assert_eq!(j.state, JobState::Running);
    }

    #[test]
    #[should_panic(expected = "illegal transition")]
    fn illegal_transition_panics() {
        let mut j = Job::new(JobId(1), spec(), SimTime::ZERO);
        j.transition(JobState::Completed, SimTime::from_secs(1));
    }

    #[test]
    fn revision_moves_with_every_transition() {
        let mut j = Job::new(JobId(1), spec(), SimTime::ZERO);
        assert_eq!(j.revision(), 0);
        j.transition(JobState::Running, SimTime::from_secs(1));
        assert_eq!(j.revision(), 1);
        j.transition(JobState::Suspended, SimTime::from_secs(2));
        j.transition(JobState::Running, SimTime::from_secs(3));
        assert_eq!(j.revision(), 3, "suspend/resume must move the revision");
        j.touch();
        assert_eq!(j.revision(), 4);
    }

    #[test]
    fn terminal_states_have_no_exits() {
        use JobState::*;
        for terminal in [Completed, Cancelled] {
            for next in [Pending, Running, Completed, Requeued, Cancelled, Suspended] {
                assert!(!terminal.can_transition_to(next));
            }
        }
    }
}
