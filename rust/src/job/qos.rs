//! Quality-of-Service classes and the QoS table.
//!
//! The paper's setup uses QoS-based preemption: spot jobs carry a dedicated
//! low-priority QoS that (a) marks them preemptable by Normal-QoS jobs and
//! (b) carries a `MaxTRESPerUser` cap the cron agent adjusts dynamically to
//! keep the idle-node reserve free (paper Section II.B).

use super::user::UserId;
use crate::util::fxhash::FxHashMap;
use std::collections::BTreeMap;

/// QoS classes relevant to the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum QosClass {
    /// Regular interactive jobs.
    Normal,
    /// Preemptable spot jobs.
    Spot,
}

impl QosClass {
    /// Report label.
    pub fn label(self) -> &'static str {
        match self {
            QosClass::Normal => "normal",
            QosClass::Spot => "spot",
        }
    }
}

impl std::fmt::Display for QosClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Per-class QoS configuration.
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// Scheduling priority contribution (higher = earlier).
    pub priority: u32,
    /// May jobs of this class be preempted by Normal jobs?
    pub preemptable: bool,
    /// `MaxTRESPerUser` (cores) — cap on concurrently-used cores per user in
    /// this QoS. `None` = unlimited. The cron agent updates the Spot cap at
    /// runtime.
    pub max_tres_per_user: Option<u32>,
    /// Cap on total cores used by this QoS across all users (the cron agent
    /// uses this as the global spot ceiling protecting the reserve).
    pub max_tres_total: Option<u32>,
}

/// The QoS table: configuration plus per-user usage accounting.
///
/// Per-user usage keys on the compact `(QosClass, UserId)` pair and retires
/// entries at zero, so the table tracks users with cores *currently* charged
/// under the class — not every user the daemon has ever admitted.
#[derive(Debug, Clone)]
pub struct QosTable {
    normal: QosConfig,
    spot: QosConfig,
    usage: FxHashMap<(QosClass, UserId), u32>,
    total_usage: BTreeMap<QosClass, u32>,
}

impl Default for QosTable {
    fn default() -> Self {
        Self::new()
    }
}

impl QosTable {
    /// The paper's configuration: Normal outranks Spot; spot preemptable;
    /// no static caps (the cron agent installs dynamic ones).
    pub fn new() -> Self {
        Self {
            normal: QosConfig {
                priority: 1000,
                preemptable: false,
                max_tres_per_user: None,
                max_tres_total: None,
            },
            spot: QosConfig {
                priority: 10,
                preemptable: true,
                max_tres_per_user: None,
                max_tres_total: None,
            },
            usage: FxHashMap::default(),
            total_usage: BTreeMap::new(),
        }
    }

    /// Config for a class.
    pub fn config(&self, class: QosClass) -> &QosConfig {
        match class {
            QosClass::Normal => &self.normal,
            QosClass::Spot => &self.spot,
        }
    }

    /// Mutable config (cron agent updates `max_tres_*`).
    pub fn config_mut(&mut self, class: QosClass) -> &mut QosConfig {
        match class {
            QosClass::Normal => &mut self.normal,
            QosClass::Spot => &mut self.spot,
        }
    }

    /// Cores currently in use by `user` under `class`.
    pub fn usage(&self, class: QosClass, user: UserId) -> u32 {
        self.usage.get(&(class, user)).copied().unwrap_or(0)
    }

    /// Cores currently in use by all users under `class`.
    pub fn total_usage(&self, class: QosClass) -> u32 {
        self.total_usage.get(&class).copied().unwrap_or(0)
    }

    /// Would starting a job of `cores` for `user` under `class` violate the
    /// QoS limits?
    pub fn admits(&self, class: QosClass, user: UserId, cores: u32) -> bool {
        let cfg = self.config(class);
        if let Some(cap) = cfg.max_tres_per_user {
            if self.usage(class, user) + cores > cap {
                return false;
            }
        }
        if let Some(cap) = cfg.max_tres_total {
            if self.total_usage(class) + cores > cap {
                return false;
            }
        }
        true
    }

    /// Record a job start.
    pub fn charge(&mut self, class: QosClass, user: UserId, cores: u32) {
        *self.usage.entry((class, user)).or_default() += cores;
        *self.total_usage.entry(class).or_default() += cores;
    }

    /// Record a job end/preemption. Zeroed per-user entries are removed so
    /// the table stays sized to users currently charged.
    pub fn credit(&mut self, class: QosClass, user: UserId, cores: u32) {
        let u = self.usage.get_mut(&(class, user)).expect("credit without charge");
        assert!(*u >= cores, "crediting more than charged");
        *u -= cores;
        if *u == 0 {
            self.usage.remove(&(class, user));
        }
        let t = self.total_usage.get_mut(&class).expect("credit without charge");
        *t -= cores;
    }

    /// (class, user) pairs with nonzero charged usage (the live table size).
    pub fn tracked(&self) -> usize {
        self.usage.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_ordering() {
        let t = QosTable::new();
        assert!(t.config(QosClass::Normal).priority > t.config(QosClass::Spot).priority);
        assert!(t.config(QosClass::Spot).preemptable);
        assert!(!t.config(QosClass::Normal).preemptable);
    }

    #[test]
    fn per_user_cap_enforced() {
        let mut t = QosTable::new();
        t.config_mut(QosClass::Spot).max_tres_per_user = Some(100);
        let u = UserId(1);
        assert!(t.admits(QosClass::Spot, u, 100));
        t.charge(QosClass::Spot, u, 60);
        assert!(t.admits(QosClass::Spot, u, 40));
        assert!(!t.admits(QosClass::Spot, u, 41));
        // Another user has their own budget.
        assert!(t.admits(QosClass::Spot, UserId(2), 100));
    }

    #[test]
    fn total_cap_enforced_across_users() {
        let mut t = QosTable::new();
        t.config_mut(QosClass::Spot).max_tres_total = Some(100);
        t.charge(QosClass::Spot, UserId(1), 80);
        assert!(!t.admits(QosClass::Spot, UserId(2), 30));
        assert!(t.admits(QosClass::Spot, UserId(2), 20));
    }

    #[test]
    fn charge_credit_roundtrip() {
        let mut t = QosTable::new();
        let u = UserId(3);
        t.charge(QosClass::Normal, u, 64);
        assert_eq!(t.usage(QosClass::Normal, u), 64);
        assert_eq!(t.total_usage(QosClass::Normal), 64);
        t.credit(QosClass::Normal, u, 64);
        assert_eq!(t.usage(QosClass::Normal, u), 0);
        assert_eq!(t.total_usage(QosClass::Normal), 0);
    }

    #[test]
    #[should_panic(expected = "crediting more than charged")]
    fn over_credit_panics() {
        let mut t = QosTable::new();
        t.charge(QosClass::Spot, UserId(1), 10);
        t.credit(QosClass::Spot, UserId(1), 11);
    }

    #[test]
    fn unlimited_by_default() {
        let t = QosTable::new();
        assert!(t.admits(QosClass::Spot, UserId(1), u32::MAX / 2));
    }

    #[test]
    fn usage_table_retires_zeroed_pairs() {
        let mut t = QosTable::new();
        for u in 0..5_000u32 {
            t.charge(QosClass::Spot, UserId(u), 2);
        }
        assert_eq!(t.tracked(), 5_000);
        for u in 0..5_000u32 {
            t.credit(QosClass::Spot, UserId(u), 2);
        }
        assert_eq!(t.tracked(), 0);
        assert_eq!(t.total_usage(QosClass::Spot), 0);
    }
}
