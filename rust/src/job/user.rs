//! Per-user resource limits and accounting.
//!
//! The MIT SuperCloud enforces per-user core limits on the interactive
//! partition (4096 cores on the partition used in the paper's production
//! experiments). The cron-agent approach sizes the idle-node reserve to this
//! limit so *any* single user's next interactive job fits without preemption
//! on the submit path.

use crate::util::fxhash::FxHashMap;
use std::collections::BTreeMap;

/// User identifier.
///
/// Deliberately a compact interned `u32` (not a name string): the fairshare
/// tables and queue buckets key on it millions of times per scaling run, so
/// lookups hash one word and the tables stay cache-dense at 10⁶ users.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct UserId(pub u32);

impl std::fmt::Display for UserId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "user{}", self.0)
    }
}

/// Per-user limits (normal QoS; spot limits live in the QoS table).
#[derive(Debug, Clone, Copy)]
pub struct UserLimits {
    /// Maximum concurrently allocated cores for interactive jobs.
    pub max_cores: u32,
}

impl Default for UserLimits {
    fn default() -> Self {
        // The paper's production partition enforces 4096 cores/user.
        Self { max_cores: 4096 }
    }
}

/// Tracks interactive-core usage per user against limits.
///
/// The usage table holds **only users with nonzero charged cores**: entries
/// are retired the moment their usage returns to zero, so a heavy-tail
/// million-user submission history costs memory proportional to the users
/// *currently running*, not every user ever seen.
#[derive(Debug, Clone, Default)]
pub struct UserAccounting {
    limits: BTreeMap<UserId, UserLimits>,
    default_limits: UserLimits,
    usage: FxHashMap<UserId, u32>,
}

impl UserAccounting {
    /// Create with the given default limit.
    pub fn with_default_limit(max_cores: u32) -> Self {
        Self {
            default_limits: UserLimits { max_cores },
            ..Default::default()
        }
    }

    /// Set a user-specific limit.
    pub fn set_limit(&mut self, user: UserId, limits: UserLimits) {
        self.limits.insert(user, limits);
    }

    /// Effective limit for a user.
    pub fn limit(&self, user: UserId) -> UserLimits {
        self.limits.get(&user).copied().unwrap_or(self.default_limits)
    }

    /// Cores currently charged to the user.
    pub fn usage(&self, user: UserId) -> u32 {
        self.usage.get(&user).copied().unwrap_or(0)
    }

    /// Whether the user may start a job of `cores` more.
    pub fn admits(&self, user: UserId, cores: u32) -> bool {
        self.usage(user) + cores <= self.limit(user).max_cores
    }

    /// Charge usage at job start.
    pub fn charge(&mut self, user: UserId, cores: u32) {
        *self.usage.entry(user).or_default() += cores;
    }

    /// Credit usage at job end. Entries are removed when they hit zero so
    /// the table never accumulates dead users.
    pub fn credit(&mut self, user: UserId, cores: u32) {
        let u = self.usage.get_mut(&user).expect("credit without charge");
        assert!(*u >= cores, "crediting more than charged");
        *u -= cores;
        if *u == 0 {
            self.usage.remove(&user);
        }
    }

    /// Users with nonzero charged usage (the live table size).
    pub fn tracked(&self) -> usize {
        self.usage.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_limit_is_papers() {
        let acc = UserAccounting::default();
        assert_eq!(acc.limit(UserId(9)).max_cores, 4096);
    }

    #[test]
    fn admits_until_limit() {
        let mut acc = UserAccounting::with_default_limit(100);
        let u = UserId(1);
        assert!(acc.admits(u, 100));
        acc.charge(u, 70);
        assert!(acc.admits(u, 30));
        assert!(!acc.admits(u, 31));
        acc.credit(u, 70);
        assert!(acc.admits(u, 100));
    }

    #[test]
    fn per_user_override() {
        let mut acc = UserAccounting::with_default_limit(100);
        acc.set_limit(UserId(2), UserLimits { max_cores: 10 });
        assert!(acc.admits(UserId(1), 100));
        assert!(!acc.admits(UserId(2), 11));
    }

    #[test]
    fn usage_table_retires_zeroed_users() {
        let mut acc = UserAccounting::default();
        for u in 0..10_000u32 {
            acc.charge(UserId(u), 4);
        }
        assert_eq!(acc.tracked(), 10_000);
        for u in 0..10_000u32 {
            acc.credit(UserId(u), 4);
        }
        // Every user drained back to zero: the table must be empty, not a
        // graveyard of zero entries.
        assert_eq!(acc.tracked(), 0);
        assert_eq!(acc.usage(UserId(42)), 0);
    }
}
