//! Job specifications: the paper's three launch types.
//!
//! * **Individual** — N tasks submitted as N separate single-task jobs; each
//!   pays a full per-job scheduling transaction.
//! * **Array** — one job with N array tasks; one scheduling transaction,
//!   N per-task dispatches.
//! * **Triple-mode** — the MIT SuperCloud launch (gridMatlab/LLMapReduce):
//!   node-based scheduling with all tasks on a node consolidated under a
//!   single execution script, so a 4096-task job on 64-core nodes needs only
//!   64 dispatches. This is what makes interactive launch fast, and what
//!   makes any added latency so visible (paper Fig 2).

use super::qos::QosClass;
use super::user::UserId;
use crate::cluster::AllocRequest;
use crate::sim::SimTime;
use std::sync::{Arc, OnceLock};

/// The launch type of a submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobType {
    /// Independent single-task jobs.
    Individual,
    /// One array job with per-task dispatch.
    Array,
    /// Consolidated node-based launch.
    TripleMode,
}

impl JobType {
    /// Label used in reports (matches the paper's figure legends).
    pub fn label(self) -> &'static str {
        match self {
            JobType::Individual => "individual",
            JobType::Array => "array",
            JobType::TripleMode => "triple-mode",
        }
    }

    /// All three, in the paper's presentation order.
    pub fn all() -> [JobType; 3] {
        [JobType::Individual, JobType::Array, JobType::TripleMode]
    }
}

impl std::fmt::Display for JobType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Immutable description of one job as the scheduler sees it.
///
/// Note an *Individual* submission of N tasks materializes as N `JobSpec`s
/// of one task each (see [`crate::workload`]); `Array`/`TripleMode`
/// submissions materialize as a single spec with `tasks = N`.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Submitting user.
    pub user: UserId,
    /// Launch type.
    pub job_type: JobType,
    /// Total tasks in this job (1 for individual jobs).
    pub tasks: u32,
    /// Cores per task (1 throughout the paper's experiments).
    pub cores_per_task: u32,
    /// QoS class: Normal (interactive) or Spot (preemptable).
    pub qos: QosClass,
    /// How long the job runs once started (simulation only; the paper
    /// measures scheduling time, not run time).
    pub run_time: SimTime,
    /// Human-readable tag for traces, reports, and (since the manifest
    /// submission path) remote clients: shared, so a 100k-job burst holds
    /// one allocation per distinct tag, not one per job.
    pub tag: Arc<str>,
}

/// The default tags are process-wide shared allocations: constructing a
/// spec costs an `Arc` clone, never a fresh string, so burst submission
/// paths stay allocation-free per job.
fn shared_tag(cell: &'static OnceLock<Arc<str>>, text: &'static str) -> Arc<str> {
    Arc::clone(cell.get_or_init(|| Arc::from(text)))
}

static INTERACTIVE_TAG: OnceLock<Arc<str>> = OnceLock::new();
static SPOT_TAG: OnceLock<Arc<str>> = OnceLock::new();

impl JobSpec {
    /// An interactive (Normal QoS) job.
    pub fn interactive(user: UserId, job_type: JobType, tasks: u32) -> Self {
        Self {
            user,
            job_type,
            tasks,
            cores_per_task: 1,
            qos: QosClass::Normal,
            run_time: SimTime::from_secs(3600),
            tag: shared_tag(&INTERACTIVE_TAG, "interactive"),
        }
    }

    /// A spot (preemptable) job.
    pub fn spot(user: UserId, job_type: JobType, tasks: u32) -> Self {
        Self {
            user,
            job_type,
            tasks,
            cores_per_task: 1,
            qos: QosClass::Spot,
            run_time: SimTime::from_secs(24 * 3600),
            tag: shared_tag(&SPOT_TAG, "spot"),
        }
    }

    /// Builder: set run time.
    pub fn with_run_time(mut self, t: SimTime) -> Self {
        self.run_time = t;
        self
    }

    /// Builder: set tag. Pass an `Arc<str>` clone to share one allocation
    /// across a burst (a `&str` allocates once here).
    pub fn with_tag(mut self, tag: impl Into<Arc<str>>) -> Self {
        self.tag = tag.into();
        self
    }

    /// Builder: set cores per task (1 throughout the paper's experiments;
    /// manifest entries may override it).
    pub fn with_cores_per_task(mut self, cores: u32) -> Self {
        self.cores_per_task = cores;
        self
    }

    /// Total cores required.
    pub fn cores(&self) -> u32 {
        self.tasks * self.cores_per_task
    }

    /// The allocation request: triple-mode jobs use node-based scheduling
    /// (whole nodes), others use core-based scheduling.
    pub fn alloc_request(&self, cores_per_node: u32) -> AllocRequest {
        match self.job_type {
            JobType::TripleMode => {
                AllocRequest::WholeNodes(self.cores().div_ceil(cores_per_node))
            }
            _ => AllocRequest::Cores(self.cores()),
        }
    }

    /// Number of dispatch RPCs the controller must issue to launch this job:
    /// per task for individual/array, per node script for triple-mode.
    pub fn dispatch_count(&self, cores_per_node: u32) -> u64 {
        match self.job_type {
            JobType::TripleMode => self.cores().div_ceil(cores_per_node) as u64,
            _ => self.tasks as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triple_mode_consolidates_dispatches() {
        let s = JobSpec::interactive(UserId(1), JobType::TripleMode, 4096);
        assert_eq!(s.dispatch_count(64), 64);
        assert_eq!(s.alloc_request(64), AllocRequest::WholeNodes(64));
    }

    #[test]
    fn array_dispatches_per_task() {
        let s = JobSpec::interactive(UserId(1), JobType::Array, 4096);
        assert_eq!(s.dispatch_count(64), 4096);
        assert_eq!(s.alloc_request(64), AllocRequest::Cores(4096));
    }

    #[test]
    fn triple_mode_rounds_nodes_up() {
        let s = JobSpec::interactive(UserId(1), JobType::TripleMode, 100);
        assert_eq!(s.alloc_request(64), AllocRequest::WholeNodes(2));
        assert_eq!(s.dispatch_count(64), 2);
    }

    #[test]
    fn consolidation_ratio_is_paper_example() {
        // Paper: "from 4096 to 64, if 64 array tasks are consolidated"
        let s = JobSpec::interactive(UserId(1), JobType::TripleMode, 4096);
        let ratio = 4096 / s.dispatch_count(64);
        assert_eq!(ratio, 64);
    }

    #[test]
    fn spot_defaults() {
        let s = JobSpec::spot(UserId(2), JobType::TripleMode, 512);
        assert_eq!(s.qos, QosClass::Spot);
        assert_eq!(s.cores(), 512);
    }

    #[test]
    fn default_tags_share_one_allocation() {
        let a = JobSpec::interactive(UserId(1), JobType::Individual, 1);
        let b = JobSpec::interactive(UserId(2), JobType::Array, 8);
        assert_eq!(&*a.tag, "interactive");
        assert!(Arc::ptr_eq(&a.tag, &b.tag), "default tag must be shared");
        let s = JobSpec::spot(UserId(9), JobType::TripleMode, 64);
        assert_eq!(&*s.tag, "spot");
    }

    #[test]
    fn with_tag_accepts_str_and_arc() {
        let shared: Arc<str> = Arc::from("fig2-live");
        let a = JobSpec::interactive(UserId(1), JobType::Array, 4).with_tag(Arc::clone(&shared));
        let b = JobSpec::interactive(UserId(1), JobType::Array, 4).with_tag("plain");
        assert!(Arc::ptr_eq(&a.tag, &shared));
        assert_eq!(&*b.tag, "plain");
        assert_eq!(
            JobSpec::interactive(UserId(1), JobType::Array, 4)
                .with_cores_per_task(2)
                .cores(),
            8
        );
    }
}
