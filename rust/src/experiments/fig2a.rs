//! Fig 2a: TX-2500 development cluster (608 tasks), baseline vs automatic
//! scheduler preemption (REQUEUE), single and dual partition configurations,
//! three job types.

use super::{ratio, Case, ExpReport, ExpRow, Expectation};
use crate::cluster::{topology, PartitionLayout};
use crate::job::JobType;
use crate::preempt::{PreemptApproach, PreemptMode};
use crate::sim::SchedCosts;

const TASKS: u32 = 608;

/// Run the experiment **live**: the same workload shapes replayed over TCP
/// against a running daemon via manifest submission, latencies read from
/// remote `WAIT` responses (see [`super::live`]).
pub fn run_live(seed: u64) -> ExpReport {
    super::live::run(seed)
}

/// Run the experiment (in-process simulation).
pub fn run(seed: u64) -> ExpReport {
    let mut rows = Vec::new();
    for jt in JobType::all() {
        for (series, layout, fill) in [
            ("baseline", PartitionLayout::Dual, 0u32),
            ("auto/REQUEUE/single", PartitionLayout::Single, TASKS),
            ("auto/REQUEUE/dual", PartitionLayout::Dual, TASKS),
        ] {
            let mut case = Case::baseline(
                SchedCosts::dedicated(),
                topology::tx2500,
                layout,
                jt,
                TASKS,
            )
            .with_seed(seed);
            if fill > 0 {
                case = case.with_preemption(
                    PreemptApproach::AutoScheduler {
                        mode: PreemptMode::Requeue,
                    },
                    fill,
                    1,
                );
            }
            let r = super::run_case(&case);
            rows.push(ExpRow {
                series: series.to_string(),
                job_type: jt,
                tasks: TASKS,
                total_secs: r.total_secs,
                per_task_secs: r.per_task_secs,
            });
        }
    }

    let report = ExpReport {
        id: "fig2a",
        title: "TX-2500: baseline vs scheduler auto-preemption (REQUEUE), single/dual partition",
        expectations: expectations(&rows),
        rows,
    };
    report
}

fn expectations(rows: &[ExpRow]) -> Vec<Expectation> {
    let get = |series: &str, jt: JobType| {
        rows.iter()
            .find(|r| r.series == series && r.job_type == jt)
            .expect("row")
    };
    let base_tri = get("baseline", JobType::TripleMode);
    let base_ind = get("baseline", JobType::Individual);
    let base_arr = get("baseline", JobType::Array);
    let tri_single = get("auto/REQUEUE/single", JobType::TripleMode);
    let tri_dual = get("auto/REQUEUE/dual", JobType::TripleMode);

    let tri_speedup = ratio(base_ind, base_tri).min(ratio(base_arr, base_tri));
    let mut out = vec![Expectation {
        claim: "triple-mode baseline dispatches ≥50x faster per task than individual/array",
        holds: tri_speedup >= 50.0,
        detail: format!("measured {:.0}x", tri_speedup),
    }];
    out.push(Expectation {
        claim: "auto preemption is slower than baseline (triple-mode, both layouts)",
        holds: tri_single.per_task_secs > base_tri.per_task_secs
            && tri_dual.per_task_secs > base_tri.per_task_secs,
        detail: format!(
            "single {:.1}x, dual {:.1}x baseline",
            ratio(tri_single, base_tri),
            ratio(tri_dual, base_tri)
        ),
    });
    out.push(Expectation {
        claim: "single partition is slower than dual (preemption path)",
        holds: tri_single.per_task_secs > tri_dual.per_task_secs,
        detail: format!("single/dual = {:.2}x", ratio(tri_single, tri_dual)),
    });
    out.push(Expectation {
        claim: "preemption effect is most significant for triple-mode jobs",
        holds: {
            let tri_deg = ratio(tri_dual, base_tri);
            let ind_deg = ratio(get("auto/REQUEUE/dual", JobType::Individual), base_ind);
            let arr_deg = ratio(get("auto/REQUEUE/dual", JobType::Array), base_arr);
            tri_deg > ind_deg && tri_deg > arr_deg
        },
        detail: "degradation ratio comparison".to_string(),
    });
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn shape_matches_paper() {
        let report = super::run(1);
        assert!(report.check(), "\n{}", report.render());
    }
}
