//! Ablations on the paper's design choices (DESIGN.md §7):
//!
//! 1. **Reserve size** — the paper pins the idle reserve to the per-user
//!    limit; smaller reserves delay back-to-back jobs, larger reserves cost
//!    spot capacity.
//! 2. **Cron interval** — the 1-minute crontab bounds the wait of a second
//!    job arriving inside one interval.
//! 3. **LIFO vs FIFO victim order** — youngest-first preserves old spot
//!    jobs' progress.

use super::{ExpReport, ExpRow, Expectation};
use crate::cluster::{topology, PartitionLayout};
use crate::job::{JobState, JobType, UserId};
use crate::preempt::lifo::{self, Demand, Order};
use crate::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use crate::sched::{Scheduler, SchedulerConfig};
use crate::sim::{SchedCosts, SimTime};
use crate::workload::{interactive_burst, spot_fill};

/// Run all three ablations.
pub fn run(seed: u64) -> ExpReport {
    let mut rows = Vec::new();
    let mut expectations = Vec::new();

    // ---- 1. reserve size sweep -------------------------------------------
    let mut waits = Vec::new();
    for reserve in [1u32, 2, 5, 10] {
        let (second_wait, spot_nodes) = back_to_back(reserve, 5, SimTime::from_secs(60), seed);
        waits.push((reserve, second_wait, spot_nodes));
        rows.push(ExpRow {
            series: format!("reserve={reserve} nodes (spot capacity {spot_nodes} nodes)"),
            job_type: JobType::TripleMode,
            tasks: 160,
            total_secs: second_wait,
            per_task_secs: second_wait / 160.0,
        });
    }
    expectations.push(Expectation {
        claim: "a reserve >= the job size makes back-to-back waits small; smaller reserves pay the cron delay",
        holds: {
            let small = waits.iter().find(|w| w.0 == 1).unwrap().1;
            let big = waits.iter().find(|w| w.0 == 10).unwrap().1;
            big < small
        },
        detail: waits
            .iter()
            .map(|(r, w, _)| format!("reserve {r}: {w:.1}s"))
            .collect::<Vec<_>>()
            .join(", "),
    });
    expectations.push(Expectation {
        claim: "larger reserves cost spot capacity (the utilization trade-off)",
        holds: {
            let cap_small = waits.iter().find(|w| w.0 == 1).unwrap().2;
            let cap_big = waits.iter().find(|w| w.0 == 10).unwrap().2;
            cap_big < cap_small
        },
        detail: waits
            .iter()
            .map(|(r, _, c)| format!("reserve {r}: {c} spot nodes"))
            .collect::<Vec<_>>()
            .join(", "),
    });

    // ---- 2. cron interval sweep --------------------------------------------
    let mut interval_rows = Vec::new();
    for interval in [30u64, 60, 300] {
        let (second_wait, _) = back_to_back(5, 5, SimTime::from_secs(interval), seed);
        interval_rows.push((interval, second_wait));
        rows.push(ExpRow {
            series: format!("cron interval={interval}s"),
            job_type: JobType::TripleMode,
            tasks: 160,
            total_secs: second_wait,
            per_task_secs: second_wait / 160.0,
        });
    }
    expectations.push(Expectation {
        claim: "a longer cron interval lengthens the second job's worst-case wait",
        holds: {
            let w30 = interval_rows.iter().find(|x| x.0 == 30).unwrap().1;
            let w300 = interval_rows.iter().find(|x| x.0 == 300).unwrap().1;
            w300 > w30
        },
        detail: interval_rows
            .iter()
            .map(|(i, w)| format!("{i}s: {w:.1}s"))
            .collect::<Vec<_>>()
            .join(", "),
    });

    // ---- 3. LIFO vs FIFO victim order ---------------------------------------
    let victims = [
        lifo::Victim {
            job: crate::job::JobId(1),
            queue_time: SimTime::from_secs(100), // oldest
            cores: 64,
            whole_nodes: 1,
        },
        lifo::Victim {
            job: crate::job::JobId(2),
            queue_time: SimTime::from_secs(500),
            cores: 64,
            whole_nodes: 1,
        },
        lifo::Victim {
            job: crate::job::JobId(3),
            queue_time: SimTime::from_secs(900), // youngest
            cores: 64,
            whole_nodes: 1,
        },
    ];
    let lifo_sel = lifo::select_victims(&victims, Demand::Cores(100), Order::YoungestFirst).unwrap();
    let fifo_sel = lifo::select_victims(&victims, Demand::Cores(100), Order::OldestFirst).unwrap();
    expectations.push(Expectation {
        claim: "LIFO spares the oldest spot job; FIFO kills it first",
        holds: !lifo_sel.contains(&crate::job::JobId(1)) && fifo_sel.contains(&crate::job::JobId(1)),
        detail: format!("LIFO selects {lifo_sel:?}, FIFO selects {fifo_sel:?}"),
    });

    ExpReport {
        id: "ablations",
        title: "Design-choice ablations: reserve size, cron interval, victim order",
        rows,
        expectations,
    }
}

/// Submit two 5-node interactive jobs back-to-back (1 s apart) on a
/// spot-loaded TX-2500 with the given reserve and cron interval. Returns
/// (second job scheduling time in seconds, spot capacity in nodes).
fn back_to_back(reserve_nodes: u32, job_nodes: u32, cron_interval: SimTime, seed: u64) -> (f64, u32) {
    let mut costs = SchedCosts::dedicated();
    costs.cron_interval = cron_interval;
    let cfg = SchedulerConfig::baseline(costs, PartitionLayout::Dual)
        .with_user_limit(job_nodes * 32)
        .with_phase_seed(seed)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig { reserve_nodes },
        });
    let mut sched = Scheduler::new(topology::tx2500(), cfg);
    let horizon = SimTime::from_secs(4 * 3600);

    // Fill spot to the ceiling.
    let fill = spot_fill(UserId(900), 19 * 32, 6);
    let ids = sched.submit_burst(fill.clone());
    let _ = sched.run_until_dispatched(&ids, SimTime::from_secs(600));
    sched.run_for(SimTime::from_secs(400)); // settle to steady state
    let spot_nodes: u32 = ids
        .iter()
        .filter(|&&id| sched.job(id).map(|j| j.state) == Some(JobState::Running))
        .map(|&id| {
            sched
                .cluster()
                .allocation_of(id)
                .map(|a| a.node_count() as u32)
                .unwrap_or(0)
        })
        .sum();

    let tasks = job_nodes * 32;
    let j1 = sched.submit_burst(interactive_burst(UserId(1), JobType::TripleMode, tasks));
    assert!(sched.run_until_dispatched(&j1, horizon));
    let j2 = sched.submit_burst(interactive_burst(UserId(2), JobType::TripleMode, tasks));
    assert!(sched.run_until_dispatched(&j2, horizon), "second job stuck");
    let m = sched.log().measure(&j2).expect("measured");
    (m.total_secs, spot_nodes)
}

#[cfg(test)]
mod tests {
    #[test]
    fn ablation_shapes_hold() {
        let report = super::run(1);
        assert!(report.check(), "\n{}", report.render());
    }
}
