//! Table I: the experiment matrix. Runs one (scaled-down) representative
//! case per matrix row as a coverage smoke test and records which figure
//! regenerates the full panel.

use super::{Case, ExpReport, ExpRow, Expectation};
use crate::cluster::{topology, PartitionLayout};
use crate::job::JobType;
use crate::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use crate::sim::SchedCosts;

/// Run the matrix.
pub fn run(seed: u64) -> ExpReport {
    // (series label, approach, layout, fill) — scaled to TX-2500 for speed;
    // the full-size panels live in fig2a..fig2g.
    let matrix: Vec<(&'static str, PreemptApproach, PartitionLayout, u32)> = vec![
        (
            "auto/REQUEUE/single (figs 2a-2c)",
            PreemptApproach::AutoScheduler {
                mode: PreemptMode::Requeue,
            },
            PartitionLayout::Single,
            608,
        ),
        (
            "auto/REQUEUE/dual (figs 2a-2c)",
            PreemptApproach::AutoScheduler {
                mode: PreemptMode::Requeue,
            },
            PartitionLayout::Dual,
            608,
        ),
        (
            "auto/CANCEL/single (fig 2d)",
            PreemptApproach::AutoScheduler {
                mode: PreemptMode::Cancel,
            },
            PartitionLayout::Single,
            608,
        ),
        (
            "auto/CANCEL/dual (fig 2e)",
            PreemptApproach::AutoScheduler {
                mode: PreemptMode::Cancel,
            },
            PartitionLayout::Dual,
            608,
        ),
        (
            "manual/REQUEUE/dual (fig 2f)",
            PreemptApproach::Manual {
                mode: PreemptMode::Requeue,
            },
            PartitionLayout::Dual,
            608,
        ),
        (
            "cron/REQUEUE/dual (fig 2g)",
            PreemptApproach::CronAgent {
                mode: PreemptMode::Requeue,
                cfg: CronAgentConfig { reserve_nodes: 5 },
            },
            PartitionLayout::Dual,
            448, // leave the 5-node reserve free under the agent's ceiling
        ),
    ];

    let mut rows = Vec::new();
    let mut all_ran = true;
    for jt in JobType::all() {
        for (series, approach, layout, fill) in &matrix {
            let tasks = match approach {
                // The cron approach schedules into the reserve: size the
                // burst to the reserve (and the user limit).
                PreemptApproach::CronAgent { .. } => 160,
                _ => 608,
            };
            let case = Case::baseline(
                SchedCosts::dedicated(),
                topology::tx2500,
                *layout,
                jt,
                tasks,
            )
            .with_seed(seed)
            .with_user_limit(if matches!(approach, PreemptApproach::CronAgent { .. }) {
                160
            } else {
                4096
            })
            .with_preemption(approach.clone(), *fill, 1);
            let r = super::run_case(&case);
            all_ran &= r.total_secs > 0.0;
            rows.push(ExpRow {
                series: series.to_string(),
                job_type: jt,
                tasks,
                total_secs: r.total_secs,
                per_task_secs: r.per_task_secs,
            });
        }
    }

    // The Lua row from Table I is a negative result: covered by unit tests
    // in preempt::lua (the plugin detects but cannot act).
    let expectations = vec![
        Expectation {
            claim: "every Table I cell (approach x mode x partition x job type) executes",
            holds: all_ran && rows.len() == 18,
            detail: format!("{} cells ran", rows.len()),
        },
        Expectation {
            claim: "Lua submit-plugin row: detection works, commands fail (negative result)",
            holds: {
                use crate::preempt::lua::*;
                let job = crate::job::Job::new(
                    crate::job::JobId(1),
                    crate::job::JobSpec::interactive(crate::job::UserId(1), JobType::Array, 64),
                    crate::sim::SimTime::ZERO,
                );
                let out = LuaSubmitPlugin.job_submit(&job, &mut DenyAllGate);
                out.observed_job_cores == 64 && out.preempt_attempt.is_err()
            },
            detail: "preempt::lua::DenyAllGate".into(),
        },
    ];
    ExpReport {
        id: "table1",
        title: "Table I experiment matrix (scaled to TX-2500; full panels in fig2a-g)",
        rows,
        expectations,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn matrix_covers_all_cells() {
        let report = super::run(1);
        assert!(report.check(), "\n{}", report.render());
    }
}
