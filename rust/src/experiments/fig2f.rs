//! Fig 2f: manual preemption (modified sbatch: requeue-then-submit), dual
//! partition, 4096 cores on the production reservation, vs baseline.
//! Scheduling time measured **from preemption start**.

use super::{ratio, Case, ExpReport, ExpRow, Expectation};
use crate::cluster::{topology, PartitionLayout};
use crate::job::JobType;
use crate::preempt::{PreemptApproach, PreemptMode};
use crate::sim::SchedCosts;

const TASKS: u32 = 4096;

/// Run the experiment.
pub fn run(seed: u64) -> ExpReport {
    let mut rows = Vec::new();
    for jt in JobType::all() {
        for (series, fill) in [("baseline", 0u32), ("manual/REQUEUE/dual", TASKS)] {
            let mut case = Case::baseline(
                SchedCosts::production(),
                topology::txgreen_reservation,
                PartitionLayout::Dual,
                jt,
                TASKS,
            )
            .with_seed(seed);
            if fill > 0 {
                case = case.with_preemption(
                    PreemptApproach::Manual {
                        mode: PreemptMode::Requeue,
                    },
                    fill,
                    1,
                );
            }
            let r = super::run_case(&case);
            rows.push(ExpRow {
                series: series.to_string(),
                job_type: jt,
                tasks: TASKS,
                total_secs: r.total_secs,
                per_task_secs: r.per_task_secs,
            });
        }
    }

    let get = |series: &str, jt: JobType| {
        rows.iter()
            .find(|r| r.series == series && r.job_type == jt)
            .expect("row")
            .clone()
    };
    let base_tri = get("baseline", JobType::TripleMode);
    let man_tri = get("manual/REQUEUE/dual", JobType::TripleMode);
    let man_ind = get("manual/REQUEUE/dual", JobType::Individual);
    let man_arr = get("manual/REQUEUE/dual", JobType::Array);

    let expectations = vec![
        Expectation {
            claim: "individual/array with manual preemption are on par with baseline (<2x)",
            holds: ratio(&man_ind, &get("baseline", JobType::Individual)) < 2.0
                && ratio(&man_arr, &get("baseline", JobType::Array)) < 2.0,
            detail: format!(
                "individual {:.2}x, array {:.2}x baseline",
                ratio(&man_ind, &get("baseline", JobType::Individual)),
                ratio(&man_arr, &get("baseline", JobType::Array))
            ),
        },
        Expectation {
            claim: "triple-mode manual preemption ~10x its baseline but single-digit seconds",
            holds: {
                let deg = ratio(&man_tri, &base_tri);
                (2.0..60.0).contains(&deg) && man_tri.total_secs < 30.0
            },
            detail: format!(
                "{:.1}x baseline, total {:.2}s",
                ratio(&man_tri, &base_tri),
                man_tri.total_secs
            ),
        },
        Expectation {
            claim: "triple-mode manual is ~7-11x faster than individual/array with preemption",
            holds: {
                let vs_ind = man_ind.total_secs / man_tri.total_secs;
                let vs_arr = man_arr.total_secs / man_tri.total_secs;
                vs_ind >= 3.0 && vs_arr >= 3.0
            },
            detail: format!(
                "vs individual {:.1}x, vs array {:.1}x",
                man_ind.total_secs / man_tri.total_secs,
                man_arr.total_secs / man_tri.total_secs
            ),
        },
    ];
    ExpReport {
        id: "fig2f",
        title: "TX-Green production: manual (sbatch-requeue) preemption vs baseline, 4096 cores",
        rows,
        expectations,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn shape_matches_paper() {
        let report = super::run(1);
        assert!(report.check(), "\n{}", report.render());
    }
}
