//! The paper-reproduction harness: one module per figure/table.
//!
//! Every module exposes `run(seed) -> ExpReport`; the report prints the same
//! rows/series the paper plots (scheduling time per task, log scale) plus
//! the paper's expected shape so terminal output reads as a side-by-side.
//! `benches/` wraps these, and `spotcloud experiment <id>` runs them from
//! the CLI.

pub mod ablations;
pub mod fig2a;
pub mod fig2b;
pub mod fig2c;
pub mod fig2d;
pub mod fig2e;
pub mod fig2f;
pub mod fig2g;
pub mod live;
pub mod runner;
pub mod table1;

pub use runner::{run_case, Case, CaseResult};

use crate::job::JobType;
use crate::util::fmt::{fmt_sci, fmt_seconds, Table};

/// One measured row of a figure.
#[derive(Debug, Clone)]
pub struct ExpRow {
    /// Series label (e.g. "baseline", "preempt/REQUEUE/single").
    pub series: String,
    /// Job launch type.
    pub job_type: JobType,
    /// Tasks in the burst.
    pub tasks: u32,
    /// Total scheduling time (s).
    pub total_secs: f64,
    /// Scheduling time per task (s) — the paper's y-axis.
    pub per_task_secs: f64,
}

/// A rendered experiment.
#[derive(Debug, Clone)]
pub struct ExpReport {
    /// Identifier ("fig2a", "table1", ...).
    pub id: &'static str,
    /// Figure caption (what the paper's panel shows).
    pub title: &'static str,
    /// Measured rows.
    pub rows: Vec<ExpRow>,
    /// The paper's expected shape, asserted by `check()`.
    pub expectations: Vec<Expectation>,
}

/// A checkable shape expectation (who wins, by what factor).
#[derive(Debug, Clone)]
pub struct Expectation {
    /// Human-readable claim (from the paper).
    pub claim: &'static str,
    /// Whether the measured rows satisfy it.
    pub holds: bool,
    /// Supporting detail (measured ratio etc).
    pub detail: String,
}

impl ExpReport {
    /// Render the report as an ASCII table plus the expectation checklist.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "series",
            "job type",
            "tasks",
            "total",
            "sec/task (log-scale axis)",
        ])
        .with_title(format!("== {} — {} ==", self.id, self.title));
        for r in &self.rows {
            t.row(vec![
                r.series.clone(),
                r.job_type.label().to_string(),
                r.tasks.to_string(),
                fmt_seconds(r.total_secs),
                fmt_sci(r.per_task_secs),
            ]);
        }
        let mut out = t.render();
        out.push_str("paper-shape checks:\n");
        for e in &self.expectations {
            out.push_str(&format!(
                "  [{}] {} ({})\n",
                if e.holds { "PASS" } else { "FAIL" },
                e.claim,
                e.detail
            ));
        }
        out
    }

    /// All expectations hold?
    pub fn check(&self) -> bool {
        self.expectations.iter().all(|e| e.holds)
    }

    /// Find a row.
    pub fn row(&self, series: &str, job_type: JobType) -> Option<&ExpRow> {
        self.rows
            .iter()
            .find(|r| r.series == series && r.job_type == job_type)
    }

    /// CSV of the rows.
    pub fn to_csv(&self) -> String {
        let mut t = Table::new(vec!["series", "job_type", "tasks", "total_secs", "per_task_secs"]);
        for r in &self.rows {
            t.row(vec![
                r.series.clone(),
                r.job_type.label().to_string(),
                r.tasks.to_string(),
                format!("{:.6}", r.total_secs),
                format!("{:.6e}", r.per_task_secs),
            ]);
        }
        t.to_csv()
    }
}

/// Helper: ratio of two rows' per-task times.
pub fn ratio(a: &ExpRow, b: &ExpRow) -> f64 {
    a.per_task_secs / b.per_task_secs
}

/// Shared panel for Fig 2b/2c: production reservation, auto-preemption
/// (REQUEUE) with single/dual partitions vs baseline, at a given job size.
pub(crate) fn production_preempt_panel(
    id: &'static str,
    title: &'static str,
    tasks: u32,
    seed: u64,
) -> ExpReport {
    use crate::cluster::{topology, PartitionLayout};
    use crate::preempt::{PreemptApproach, PreemptMode};
    use crate::sim::SchedCosts;

    const FILL: u32 = 4096; // the reservation is filled with triple-mode spot
    let mut rows = Vec::new();
    for jt in JobType::all() {
        for (series, layout, fill) in [
            ("baseline", PartitionLayout::Dual, 0u32),
            ("auto/REQUEUE/single", PartitionLayout::Single, FILL),
            ("auto/REQUEUE/dual", PartitionLayout::Dual, FILL),
        ] {
            let mut case = Case::baseline(
                SchedCosts::production(),
                topology::txgreen_reservation,
                layout,
                jt,
                tasks,
            )
            .with_seed(seed);
            if fill > 0 {
                case = case.with_preemption(
                    PreemptApproach::AutoScheduler {
                        mode: PreemptMode::Requeue,
                    },
                    fill,
                    1,
                );
            }
            let r = run_case(&case);
            rows.push(ExpRow {
                series: series.to_string(),
                job_type: jt,
                tasks,
                total_secs: r.total_secs,
                per_task_secs: r.per_task_secs,
            });
        }
    }

    let get = |series: &str, jt: JobType| {
        rows.iter()
            .find(|r| r.series == series && r.job_type == jt)
            .expect("row")
            .clone()
    };
    let base_tri = get("baseline", JobType::TripleMode);
    let tri_single = get("auto/REQUEUE/single", JobType::TripleMode);
    let tri_dual = get("auto/REQUEUE/dual", JobType::TripleMode);
    let expectations = vec![
        Expectation {
            claim: "preemption degrades every job type vs baseline",
            holds: JobType::all().iter().all(|&jt| {
                get("auto/REQUEUE/single", jt).per_task_secs > get("baseline", jt).per_task_secs
                    && get("auto/REQUEUE/dual", jt).per_task_secs
                        > get("baseline", jt).per_task_secs
            }),
            detail: "all six preemption rows above baseline".into(),
        },
        Expectation {
            claim: "triple-mode degradation is ~2-3 orders of magnitude",
            holds: ratio(&tri_single, &base_tri) >= 100.0 && ratio(&tri_dual, &base_tri) >= 100.0,
            detail: format!(
                "single {:.0}x, dual {:.0}x",
                ratio(&tri_single, &base_tri),
                ratio(&tri_dual, &base_tri)
            ),
        },
        Expectation {
            claim: "dual partition slightly better than single for all job types",
            holds: JobType::all().iter().all(|&jt| {
                get("auto/REQUEUE/dual", jt).per_task_secs
                    <= get("auto/REQUEUE/single", jt).per_task_secs
            }),
            detail: format!("triple: single/dual = {:.2}x", ratio(&tri_single, &tri_dual)),
        },
    ];
    ExpReport {
        id,
        title,
        rows,
        expectations,
    }
}

/// Shared panel for Fig 2d/2e: REQUEUE vs CANCEL preemption modes at 4096
/// cores on the production reservation.
pub(crate) fn mode_comparison_panel(
    id: &'static str,
    title: &'static str,
    layout: crate::cluster::PartitionLayout,
    seed: u64,
) -> ExpReport {
    use crate::cluster::topology;
    use crate::preempt::{PreemptApproach, PreemptMode};
    use crate::sim::SchedCosts;

    const TASKS: u32 = 4096;
    let mut rows = Vec::new();
    for jt in JobType::all() {
        for (series, mode) in [
            ("auto/REQUEUE", PreemptMode::Requeue),
            ("auto/CANCEL", PreemptMode::Cancel),
        ] {
            let case = Case::baseline(
                SchedCosts::production(),
                topology::txgreen_reservation,
                layout,
                jt,
                TASKS,
            )
            .with_seed(seed)
            .with_preemption(PreemptApproach::AutoScheduler { mode }, TASKS, 1);
            let r = run_case(&case);
            rows.push(ExpRow {
                series: series.to_string(),
                job_type: jt,
                tasks: TASKS,
                total_secs: r.total_secs,
                per_task_secs: r.per_task_secs,
            });
        }
    }
    let get = |series: &str, jt: JobType| {
        rows.iter()
            .find(|r| r.series == series && r.job_type == jt)
            .expect("row")
            .clone()
    };
    let expectations = vec![Expectation {
        claim: "no meaningful difference between REQUEUE and CANCEL",
        holds: JobType::all().iter().all(|&jt| {
            let r = ratio(&get("auto/REQUEUE", jt), &get("auto/CANCEL", jt));
            (0.5..=2.0).contains(&r)
        }),
        detail: JobType::all()
            .iter()
            .map(|&jt| {
                format!(
                    "{}: {:.2}x",
                    jt.label(),
                    ratio(&get("auto/REQUEUE", jt), &get("auto/CANCEL", jt))
                )
            })
            .collect::<Vec<_>>()
            .join(", "),
    }];
    ExpReport {
        id,
        title,
        rows,
        expectations,
    }
}

/// All experiment ids, for the CLI. `fig2a-live` replays the Figure-2a
/// workloads over TCP against a real daemon (manifest submission + remote
/// `WAIT` latencies) instead of driving the simulator in process.
pub const ALL: &[&str] = &[
    "fig2a", "fig2a-live", "fig2b", "fig2c", "fig2d", "fig2e", "fig2f", "fig2g", "table1",
    "ablations",
];

/// Run an experiment by id.
pub fn run_by_id(id: &str, seed: u64) -> Option<ExpReport> {
    match id {
        "fig2a" => Some(fig2a::run(seed)),
        "fig2a-live" => Some(fig2a::run_live(seed)),
        "fig2b" => Some(fig2b::run(seed)),
        "fig2c" => Some(fig2c::run(seed)),
        "fig2d" => Some(fig2d::run(seed)),
        "fig2e" => Some(fig2e::run(seed)),
        "fig2f" => Some(fig2f::run(seed)),
        "fig2g" => Some(fig2g::run(seed)),
        "table1" => Some(table1::run(seed)),
        "ablations" => Some(ablations::run(seed)),
        _ => None,
    }
}
