//! Fig 2g: the cron-agent approach vs baseline, 4096-core jobs, two runs per
//! job type.
//!
//! Setup follows the paper: the full TX-Green KNL partition (648 nodes) with
//! a 64-node reserve (= the 4096-core per-user limit), filled with "several
//! triple mode spot jobs" up to the agent's ceiling. Each job type is
//! submitted twice, more than a cron interval apart, so the agent restores
//! the reserve between runs. The cron measurements ran in a dedicated
//! (maintenance) window; the baseline was measured on production — we mirror
//! both cost presets.

use super::{Case, ExpReport, ExpRow, Expectation};
use crate::cluster::{topology, PartitionLayout};
use crate::job::{JobType, UserId};
use crate::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use crate::sched::{Scheduler, SchedulerConfig};
use crate::sim::{SchedCosts, SimTime};
use crate::workload::{interactive_burst, spot_fill};

const TASKS: u32 = 4096;
const RESERVE_NODES: u32 = 64;

/// Run the experiment.
pub fn run(seed: u64) -> ExpReport {
    let mut rows = Vec::new();

    // Baseline rows (production, idle reservation — as the paper's baseline).
    for jt in JobType::all() {
        let r = super::run_case(
            &Case::baseline(
                SchedCosts::production(),
                topology::txgreen_reservation,
                PartitionLayout::Dual,
                jt,
                TASKS,
            )
            .with_seed(seed),
        );
        rows.push(ExpRow {
            series: "baseline".into(),
            job_type: jt,
            tasks: TASKS,
            total_secs: r.total_secs,
            per_task_secs: r.per_task_secs,
        });
    }

    // Cron-agent rows: two runs per job type on a spot-loaded 648-node
    // system (dedicated window).
    for jt in JobType::all() {
        let (run1, run2) = cron_two_runs(jt, seed);
        rows.push(ExpRow {
            series: "cron-agent run 1".into(),
            job_type: jt,
            tasks: TASKS,
            total_secs: run1,
            per_task_secs: run1 / TASKS as f64,
        });
        rows.push(ExpRow {
            series: "cron-agent run 2".into(),
            job_type: jt,
            tasks: TASKS,
            total_secs: run2,
            per_task_secs: run2 / TASKS as f64,
        });
    }

    let get = |series: &str, jt: JobType| {
        rows.iter()
            .find(|r| r.series == series && r.job_type == jt)
            .expect("row")
            .clone()
    };
    let expectations = vec![
        Expectation {
            claim: "cron-agent scheduling is comparable to baseline for all job types (<15x, most <3x)",
            holds: {
                let ratios: Vec<f64> = JobType::all()
                    .iter()
                    .flat_map(|&jt| {
                        let b = get("baseline", jt).per_task_secs;
                        ["cron-agent run 1", "cron-agent run 2"]
                            .iter()
                            .map(move |s| (s.to_string(), jt, b))
                            .collect::<Vec<_>>()
                    })
                    .map(|(s, jt, b)| get(&s, jt).per_task_secs / b)
                    .collect();
                let close = ratios.iter().filter(|&&r| r < 3.0).count();
                ratios.iter().all(|&r| r < 15.0) && close >= 4
            },
            detail: JobType::all()
                .iter()
                .map(|&jt| {
                    format!(
                        "{}: {:.2}x/{:.2}x",
                        jt.label(),
                        get("cron-agent run 1", jt).per_task_secs
                            / get("baseline", jt).per_task_secs,
                        get("cron-agent run 2", jt).per_task_secs
                            / get("baseline", jt).per_task_secs
                    )
                })
                .collect::<Vec<_>>()
                .join(", "),
        },
        Expectation {
            claim: "no preemption happens on the interactive submit path (agent does it between submissions)",
            holds: true, // structural: the cron approach never preempts inline
            detail: "preempt::cron runs outside the scheduler allocation path".into(),
        },
    ];

    ExpReport {
        id: "fig2g",
        title: "TX-Green (648 nodes): cron-agent spot preemption vs baseline, 4096-core jobs x2 runs",
        rows,
        expectations,
    }
}

/// Submit the same burst twice, more than a cron interval apart, on a
/// spot-loaded 648-node cluster with a 64-node reserve. Returns the two
/// scheduling times.
fn cron_two_runs(jt: JobType, seed: u64) -> (f64, f64) {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(RESERVE_NODES * 64)
        .with_phase_seed(seed)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig {
                reserve_nodes: RESERVE_NODES,
            },
        });
    let mut sched = Scheduler::new(topology::txgreen_full(), cfg);
    let horizon = SimTime::from_secs(4 * 3600);

    // Fill spot to the ceiling: (648 - 64) nodes worth of triple-mode work
    // split across several jobs, as the paper describes.
    let fill_tasks = (648 - RESERVE_NODES) * 64;
    let fill = spot_fill(UserId(900), fill_tasks, 8);
    let ids = sched.submit_burst(fill);
    assert!(sched.run_until_dispatched(&ids, horizon), "spot fill stuck");
    sched.run_for(SimTime::from_secs(120));
    assert!(
        sched.cluster().idle_node_count() >= RESERVE_NODES,
        "reserve not idle before run 1"
    );

    // Consecutive submissions come from different users (each is entitled
    // to the full per-user limit; a single user would trip their own core
    // limit while run 1 is still executing).
    let measure_one = |sched: &mut Scheduler, user: u32| {
        let ids = sched.submit_burst(interactive_burst(UserId(user), jt, TASKS));
        assert!(sched.run_until_dispatched(&ids, horizon), "run stuck");
        sched.log().measure(&ids).expect("measured").total_secs
    };
    let run1 = measure_one(&mut sched, 1);
    // "more than a minute apart so that the cron-job script could preempt
    // the spot jobs before the second job submission"
    sched.run_for(SimTime::from_secs(150));
    let run2 = measure_one(&mut sched, 2);
    (run1, run2)
}

#[cfg(test)]
mod tests {
    #[test]
    fn shape_matches_paper() {
        let report = super::run(1);
        assert!(report.check(), "\n{}", report.render());
    }
}
