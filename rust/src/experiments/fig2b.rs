//! Fig 2b: TX-Green production (64-node reservation), **2048-core (medium)**
//! interactive jobs with automatic preemption (REQUEUE), single/dual
//! partitions, vs baseline.

use super::{production_preempt_panel, ExpReport};

/// Run the experiment.
pub fn run(seed: u64) -> ExpReport {
    production_preempt_panel(
        "fig2b",
        "TX-Green production: 2048-core jobs, auto-preemption (REQUEUE), single/dual",
        2048,
        seed,
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn shape_matches_paper() {
        let report = super::run(1);
        assert!(report.check(), "\n{}", report.render());
    }
}
