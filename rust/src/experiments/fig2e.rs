//! Fig 2e: REQUEUE vs CANCEL preemption modes, **dual** partition, 4096
//! cores on the production reservation.

use super::{mode_comparison_panel, ExpReport};
use crate::cluster::PartitionLayout;

/// Run the experiment.
pub fn run(seed: u64) -> ExpReport {
    mode_comparison_panel(
        "fig2e",
        "TX-Green production: REQUEUE vs CANCEL, dual partition, 4096 cores",
        PartitionLayout::Dual,
        seed,
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn shape_matches_paper() {
        let report = super::run(1);
        assert!(report.check(), "\n{}", report.render());
    }
}
