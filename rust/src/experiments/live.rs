//! **Live** Figure-2 replay: the same workloads as the in-process
//! experiment harness, submitted as manifests **over TCP against a running
//! daemon** through the public client API, with the virtual scheduling
//! latency read back from remote `WAIT` responses.
//!
//! The in-process harness ([`super::runner`]) measures the scheduler
//! directly; this module proves the whole coordinator stack — manifest
//! codec, admission, batched `submit_batch`, snapshot read path,
//! subscription `WAIT` — reproduces the paper's Figure-2 curves end to
//! end. Latency is *virtual* (first `Recognized` → last `DispatchDone`),
//! so the daemon's wall-clock `speedup` only bounds how long the replay
//! takes, not what it measures; see `EXPERIMENTS.md` §Live-Fig2 for the
//! observed in-process-vs-TCP deltas.

use super::{ratio, ExpReport, ExpRow, Expectation};
use crate::cluster::{topology, PartitionLayout};
use crate::coordinator::{Client, Daemon, DaemonConfig, Server};
use crate::job::JobType;
use crate::preempt::{PreemptApproach, PreemptMode};
use crate::sched::SchedulerConfig;
use crate::sim::SchedCosts;
use crate::workload::manifests;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// TX-2500 development-cluster burst size (as Fig 2a).
const TASKS: u32 = 608;

/// Virtual seconds per wall second for the replay daemons: high enough
/// that a multi-hundred-virtual-second preemption case replays in well
/// under a wall second.
const SPEEDUP: f64 = 2_000.0;

/// Wall-clock ceiling for one live `WAIT` (the measured latencies resolve
/// in fractions of a second at [`SPEEDUP`]; this only guards CI hangs).
const WAIT_TIMEOUT_SECS: f64 = 120.0;

/// Run one live case: spin up a fresh daemon + TCP server, optionally fill
/// it with spot work (manifest), submit the interactive burst (manifest),
/// and return the burst's virtual scheduling time as reported by `WAIT`.
fn run_live_case(
    layout: PartitionLayout,
    approach: PreemptApproach,
    jt: JobType,
    fill_tasks: u32,
    seed: u64,
) -> f64 {
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), layout)
        .with_approach(approach)
        .with_phase_seed(seed);
    let daemon = Daemon::new(
        topology::tx2500(),
        cfg,
        DaemonConfig {
            speedup: SPEEDUP,
            pacer_tick_ms: 1,
            // Retirement off the replay path: grace far beyond the horizon.
            retire_grace_secs: Some(86_400.0),
            ..DaemonConfig::default()
        },
    );
    let pacer = daemon.spawn_pacer();
    let server = Server::bind(Arc::clone(&daemon), "127.0.0.1:0", 2).expect("bind live daemon");
    let addr = server.local_addr().expect("local addr").to_string();
    let server_thread = std::thread::spawn(move || server.serve());
    let mut client = Client::connect_v2(&addr).expect("connect");

    if fill_tasks > 0 {
        // Fill with spot work first, as the paper does (one spot job for
        // Fig 2a–f), then let the system settle 90 virtual seconds — the
        // same protocol as the in-process runner.
        let ack = client
            .msubmit(&manifests::spot_fill(900, fill_tasks, 1))
            .expect("fill msubmit");
        assert!(ack.rejected.is_empty(), "{:?}", ack.rejected);
        let w = client
            .wait(&ack.job_ids(), WAIT_TIMEOUT_SECS)
            .expect("fill wait");
        assert!(!w.timed_out, "spot fill failed to dispatch");
        let settle_until = client.stats().expect("stats").virtual_now_secs + 90.0;
        let deadline = Instant::now() + Duration::from_secs(60);
        while client.stats().expect("stats").virtual_now_secs < settle_until {
            assert!(Instant::now() < deadline, "virtual clock stalled");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    let ack = client
        .msubmit(&manifests::fig2_burst(1, jt, TASKS, 3600.0))
        .expect("burst msubmit");
    assert!(ack.rejected.is_empty(), "{:?}", ack.rejected);
    let ids = ack.job_ids();
    let w = client.wait(&ids, WAIT_TIMEOUT_SECS).expect("burst wait");
    assert!(
        !w.timed_out && w.dispatched as usize == ids.len(),
        "live burst failed to dispatch: {w:?}"
    );
    let total_secs = w.latency_ns as f64 / 1e9;

    client.shutdown().ok();
    server_thread.join().expect("server thread");
    pacer.join().expect("pacer thread");
    total_secs
}

/// Regenerate Figure 2a **live**: baseline vs automatic scheduler
/// preemption (REQUEUE), single and dual partitions, three job types —
/// every row measured over TCP. An in-process simulator row for the
/// triple-mode baseline rides along so the live-vs-sim delta is visible
/// in the same table.
pub fn run(seed: u64) -> ExpReport {
    let mut rows = Vec::new();
    for jt in JobType::all() {
        for (series, layout, fill) in [
            ("baseline", PartitionLayout::Dual, 0u32),
            ("auto/REQUEUE/single", PartitionLayout::Single, TASKS),
            ("auto/REQUEUE/dual", PartitionLayout::Dual, TASKS),
        ] {
            let approach = if fill > 0 {
                PreemptApproach::AutoScheduler {
                    mode: PreemptMode::Requeue,
                }
            } else {
                PreemptApproach::None
            };
            let total_secs = run_live_case(layout, approach, jt, fill, seed);
            rows.push(ExpRow {
                series: series.to_string(),
                job_type: jt,
                tasks: TASKS,
                total_secs,
                per_task_secs: total_secs / TASKS as f64,
            });
        }
    }
    // The in-process reference for the same triple-mode baseline case.
    let sim = super::run_case(
        &super::Case::baseline(
            SchedCosts::dedicated(),
            topology::tx2500,
            PartitionLayout::Dual,
            JobType::TripleMode,
            TASKS,
        )
        .with_seed(seed),
    );
    rows.push(ExpRow {
        series: "baseline (in-process sim)".to_string(),
        job_type: JobType::TripleMode,
        tasks: TASKS,
        total_secs: sim.total_secs,
        per_task_secs: sim.per_task_secs,
    });

    let get = |series: &str, jt: JobType| {
        rows.iter()
            .find(|r| r.series == series && r.job_type == jt)
            .expect("row")
            .clone()
    };
    let base_tri = get("baseline", JobType::TripleMode);
    let base_ind = get("baseline", JobType::Individual);
    let base_arr = get("baseline", JobType::Array);
    let tri_single = get("auto/REQUEUE/single", JobType::TripleMode);
    let tri_dual = get("auto/REQUEUE/dual", JobType::TripleMode);
    let sim_tri = get("baseline (in-process sim)", JobType::TripleMode);
    let live_vs_sim = base_tri.per_task_secs / sim_tri.per_task_secs;

    let tri_speedup = ratio(&base_ind, &base_tri).min(ratio(&base_arr, &base_tri));
    let expectations = vec![
        Expectation {
            claim: "live: triple-mode baseline ≥25x faster per task than individual/array",
            holds: tri_speedup >= 25.0,
            detail: format!("measured {tri_speedup:.0}x over TCP"),
        },
        Expectation {
            claim: "live: auto preemption slower than baseline (triple-mode, both layouts)",
            holds: tri_single.per_task_secs > base_tri.per_task_secs
                && tri_dual.per_task_secs > base_tri.per_task_secs,
            detail: format!(
                "single {:.1}x, dual {:.1}x baseline",
                ratio(&tri_single, &base_tri),
                ratio(&tri_dual, &base_tri)
            ),
        },
        Expectation {
            claim: "live latency matches the in-process simulation (virtual metric, ±20x band)",
            holds: (0.05..=20.0).contains(&live_vs_sim),
            detail: format!(
                "live {:.3}s vs sim {:.3}s ({live_vs_sim:.2}x)",
                base_tri.total_secs, sim_tri.total_secs
            ),
        },
    ];
    ExpReport {
        id: "fig2a-live",
        title: "TX-2500 LIVE over TCP: manifest replay of baseline vs auto-preemption (REQUEUE)",
        rows,
        expectations,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn live_fig2a_shape_matches_paper_over_tcp() {
        let report = super::run(1);
        assert!(report.check(), "\n{}", report.render());
    }
}
