//! Fig 2c: TX-Green production (64-node reservation), **4096-core (large)**
//! interactive jobs with automatic preemption (REQUEUE), single/dual
//! partitions, vs baseline.

use super::{production_preempt_panel, ExpReport};

/// Run the experiment.
pub fn run(seed: u64) -> ExpReport {
    production_preempt_panel(
        "fig2c",
        "TX-Green production: 4096-core jobs, auto-preemption (REQUEUE), single/dual",
        4096,
        seed,
    )
}

#[cfg(test)]
mod tests {
    use crate::job::JobType;

    #[test]
    fn shape_matches_paper() {
        let report = super::run(1);
        assert!(report.check(), "\n{}", report.render());
    }

    #[test]
    fn triple_mode_degradation_is_orders_of_magnitude() {
        let report = super::run(1);
        let base = report.row("baseline", JobType::TripleMode).unwrap();
        let single = report.row("auto/REQUEUE/single", JobType::TripleMode).unwrap();
        let deg = single.per_task_secs / base.per_task_secs;
        // Paper: "almost three orders of magnitude".
        assert!(
            deg >= 100.0,
            "triple-mode degradation {deg:.0}x should be >= 2 orders of magnitude"
        );
    }
}
