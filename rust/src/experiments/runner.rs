//! The shared experiment runner: set up a cluster, optionally fill it with
//! spot work, submit one interactive burst, and measure its scheduling time
//! exactly as the paper does (first recognition → last dispatch; for manual
//! preemption, from preemption start).

use crate::cluster::{Cluster, PartitionLayout};
use crate::job::{JobType, UserId};
use crate::preempt::{manual, PreemptApproach};
use crate::sched::{Scheduler, SchedulerConfig};
use crate::sim::{SchedCosts, SimTime};
use crate::workload::{interactive_burst, spot_fill};

/// One experiment case.
#[derive(Clone)]
pub struct Case {
    /// Latency preset.
    pub costs: SchedCosts,
    /// Cluster construction.
    pub cluster: fn() -> Cluster,
    /// Partition layout.
    pub layout: PartitionLayout,
    /// Preemption machinery.
    pub approach: PreemptApproach,
    /// Interactive launch type.
    pub job_type: JobType,
    /// Interactive burst size (tasks).
    pub tasks: u32,
    /// Tasks of triple-mode spot fill before the burst (0 = idle cluster).
    pub spot_fill_tasks: u32,
    /// Number of spot jobs the fill is split into.
    pub spot_fill_jobs: u32,
    /// Per-user interactive core limit.
    pub user_limit: u32,
    /// Cycle-phase seed (run-to-run variance).
    pub phase_seed: u64,
}

impl Case {
    /// A baseline case (idle cluster, no preemption).
    pub fn baseline(
        costs: SchedCosts,
        cluster: fn() -> Cluster,
        layout: PartitionLayout,
        job_type: JobType,
        tasks: u32,
    ) -> Self {
        Self {
            costs,
            cluster,
            layout,
            approach: PreemptApproach::None,
            job_type,
            tasks,
            spot_fill_tasks: 0,
            spot_fill_jobs: 1,
            user_limit: 4096,
            phase_seed: 1,
        }
    }

    /// Builder: set the preemption approach + spot fill.
    pub fn with_preemption(mut self, approach: PreemptApproach, fill_tasks: u32, fill_jobs: u32) -> Self {
        self.approach = approach;
        self.spot_fill_tasks = fill_tasks;
        self.spot_fill_jobs = fill_jobs;
        self
    }

    /// Builder: phase seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.phase_seed = seed;
        self
    }

    /// Builder: user limit.
    pub fn with_user_limit(mut self, cores: u32) -> Self {
        self.user_limit = cores;
        self
    }
}

/// Measured outcome of one case.
#[derive(Debug, Clone, Copy)]
pub struct CaseResult {
    /// Total scheduling time (s).
    pub total_secs: f64,
    /// Per-task scheduling time (s).
    pub per_task_secs: f64,
    /// Preemption victims during the measurement.
    pub preemptions: u64,
}

/// Horizon generously above any expected scheduling time.
const HORIZON: SimTime = SimTime::from_secs(4 * 3600);

/// Run one case to completion and measure.
pub fn run_case(case: &Case) -> CaseResult {
    let cfg = SchedulerConfig::baseline(case.costs.clone(), case.layout)
        .with_approach(case.approach.clone())
        .with_user_limit(case.user_limit)
        .with_phase_seed(case.phase_seed);
    let mut sched = Scheduler::new((case.cluster)(), cfg);

    // Fill with spot work first, as the paper does.
    if case.spot_fill_tasks > 0 {
        let fill = spot_fill(UserId(900), case.spot_fill_tasks, case.spot_fill_jobs);
        let ids = sched.submit_burst(fill);
        assert!(
            sched.run_until_dispatched(&ids, HORIZON),
            "spot fill failed to dispatch"
        );
        // Let the system settle (cron agents run, queues drain).
        sched.run_for(SimTime::from_secs(90));
    }

    let preempt_before = sched.stats().preemptions;
    let user = UserId(1);
    let burst = interactive_burst(user, case.job_type, case.tasks);

    let measurement = if let PreemptApproach::Manual { mode } = case.approach {
        // The modified-sbatch path: requeue first, then submit; measured
        // from preemption start.
        let sub = manual::manual_submit(&mut sched, burst, mode);
        assert!(
            sched.run_until_dispatched(&sub.jobs, HORIZON),
            "manual-preempted burst failed to dispatch"
        );
        sched
            .log()
            .measure_from(sub.preempt_start, &sub.jobs)
            .expect("measured")
    } else {
        let ids = sched.submit_burst(burst);
        assert!(
            sched.run_until_dispatched(&ids, HORIZON),
            "burst failed to dispatch (approach {:?}, type {}, tasks {})",
            case.approach.label(),
            case.job_type,
            case.tasks
        );
        sched.log().measure(&ids).expect("measured")
    };

    CaseResult {
        total_secs: measurement.total_secs,
        per_task_secs: measurement.total_secs / case.tasks as f64,
        preemptions: sched.stats().preemptions - preempt_before,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology;
    use crate::preempt::PreemptMode;

    #[test]
    fn baseline_case_runs() {
        let r = run_case(&Case::baseline(
            SchedCosts::dedicated(),
            topology::tx2500,
            PartitionLayout::Dual,
            JobType::TripleMode,
            608,
        ));
        assert!(r.total_secs > 0.0 && r.total_secs < 2.0, "{r:?}");
        assert_eq!(r.preemptions, 0);
    }

    #[test]
    fn preemption_case_counts_victims() {
        let case = Case::baseline(
            SchedCosts::dedicated(),
            topology::tx2500,
            PartitionLayout::Dual,
            JobType::TripleMode,
            608,
        )
        .with_preemption(
            PreemptApproach::AutoScheduler {
                mode: PreemptMode::Requeue,
            },
            608,
            1,
        );
        let r = run_case(&case);
        assert!(r.preemptions >= 1, "{r:?}");
        assert!(r.total_secs > 5.0, "{r:?}");
    }

    #[test]
    fn manual_case_measures_from_preempt_start() {
        let case = Case::baseline(
            SchedCosts::dedicated(),
            topology::tx2500,
            PartitionLayout::Dual,
            JobType::TripleMode,
            608,
        )
        .with_preemption(
            PreemptApproach::Manual {
                mode: PreemptMode::Requeue,
            },
            608,
            1,
        );
        let r = run_case(&case);
        assert!(r.preemptions >= 1);
        assert!((0.5..30.0).contains(&r.total_secs), "{r:?}");
    }
}
