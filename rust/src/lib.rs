//! # SpotCloud
//!
//! A reproduction of *"Best of Both Worlds: High Performance Interactive and
//! Batch Launching"* (Byun et al., IEEE HPEC 2020): a Slurm-like cluster
//! scheduler (`slurmlite`) with **spot jobs** implemented four ways —
//! scheduler-automatic QoS preemption, a Lua submit-plugin (the paper's
//! negative result), manual requeue-before-submit, and the paper's
//! contribution: a privileged **cron agent** that separates preemption from
//! scheduling and keeps a pre-defined reserve of idle nodes so interactive
//! jobs always launch at baseline speed.
//!
//! The crate is organized as:
//!
//! * [`sim`] — discrete-event simulation core (virtual clock, event queue,
//!   calibrated scheduler latency cost model).
//! * [`cluster`] / [`job`] — the cluster and job substrates (nodes,
//!   partitions, QoS, per-user limits, individual/array/triple-mode jobs).
//! * [`sched`] — the scheduler: main cycle, backfill cycle, multifactor
//!   priority, node selection, per-task dispatch, event log.
//! * [`preempt`] — the four preemption engines from the paper.
//! * [`runtime`] — the PJRT/XLA bridge: loads the AOT-compiled scheduling
//!   decision kernels (JAX + Pallas, built once by `make artifacts`) and
//!   exposes them to the scheduler hot path with a pure-Rust fallback.
//! * [`coordinator`] — the runnable daemon: thread pool, versioned typed
//!   TCP protocol (v1 line grammar / v2 tagged records, see PROTOCOL.md),
//!   batch submit, remote launch-latency measurement (`WAIT`), metrics.
//! * [`workload`] / [`experiments`] — synthetic workload generators and the
//!   harness that regenerates every figure and table in the paper.
//! * [`util`], [`metrics`], [`testkit`], [`benchkit`] — std-only substrates
//!   (PRNG, CLI parsing, config files, histograms, property testing,
//!   micro-benchmarking) built from scratch for the offline environment.

pub mod benchkit;
pub mod cluster;
pub mod coordinator;
pub mod experiments;
pub mod job;
pub mod metrics;
pub mod preempt;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod testkit;
pub mod util;
pub mod workload;

/// Convenient re-exports for examples and benches.
pub mod prelude {
    pub use crate::cluster::{topology, Cluster, NodeId, PartitionLayout};
    pub use crate::job::{JobId, JobSpec, JobState, JobType, QosClass};
    pub use crate::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
    pub use crate::sched::{Scheduler, SchedulerConfig};
    pub use crate::sim::{Clock, Engine, SimTime};
    pub use crate::workload::Scenario;
}
