//! The scheduler event log — the measurement source.
//!
//! The paper measures scheduling time "from the moment the scheduler
//! recognized the job submission to the moment when its last job was
//! dispatched to the cluster for execution", read from the scheduler event
//! log. This module is that log plus the measurement helpers.

use crate::job::JobId;
use crate::sim::SimTime;
// FxHashMap: the index lookups sit on the simulator hot path and SipHash
// was 28% of burst-experiment time (EXPERIMENTS.md §Perf).
use crate::util::fxhash::FxHashMap as HashMap;

/// Log entry kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LogKind {
    /// Scheduler recognized the submission (job entered the pending queue).
    Recognized,
    /// Last task/node-script dispatch RPC for the job completed.
    DispatchDone,
    /// Job was selected as a preemption victim.
    Preempted,
    /// Requeue transaction completed (job back to pending).
    Requeued,
    /// Job reached a terminal state.
    Ended,
    /// A cron-agent pass preempted this job.
    CronPreempted,
}

impl LogKind {
    /// Stable on-disk code for the durability journal's checkpoint records.
    /// These are persisted: never renumber an existing kind, only append.
    pub fn wire_code(self) -> u8 {
        match self {
            LogKind::Recognized => 0,
            LogKind::DispatchDone => 1,
            LogKind::Preempted => 2,
            LogKind::Requeued => 3,
            LogKind::Ended => 4,
            LogKind::CronPreempted => 5,
        }
    }

    /// Inverse of [`LogKind::wire_code`].
    pub fn from_wire_code(code: u8) -> Option<Self> {
        Some(match code {
            0 => LogKind::Recognized,
            1 => LogKind::DispatchDone,
            2 => LogKind::Preempted,
            3 => LogKind::Requeued,
            4 => LogKind::Ended,
            5 => LogKind::CronPreempted,
            _ => return None,
        })
    }
}

/// One log entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LogEntry {
    /// Virtual timestamp.
    pub time: SimTime,
    /// Subject job.
    pub job: JobId,
    /// What happened.
    pub kind: LogKind,
}

/// Append-mostly scheduler event log.
///
/// Keeps O(1) first/last indexes per (job, kind): the measurement helpers
/// are called on the simulator's hot path (`run_until_dispatched` polls
/// them), and a linear scan of the log made large-burst experiments
/// quadratic (see EXPERIMENTS.md §Perf).
///
/// The log is *bounded* for long-lived daemons: once a job is retired its
/// entries are dead (the coordinator freezes everything queryable into a
/// history view first), so [`EventLog::remove_job`] drops the job's
/// indexes and marks its entries for compaction. The entries vector is
/// compacted only when at least half of it is dead (classic half-dead
/// amortization: O(1) amortized per entry, never a sweep per retirement).
/// Monotone facts survive pruning: [`EventLog::appended_total`] counts
/// every push ever (the job-table signature keys on it — a length that
/// shrank and regrew could alias), and [`EventLog::count`] keeps counting
/// entries ever logged per kind (the WAIT completion generation keys on
/// `count(Ended)`).
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    entries: Vec<LogEntry>,
    first_idx: HashMap<(JobId, LogKind), SimTime>,
    last_idx: HashMap<(JobId, LogKind), SimTime>,
    kind_counts: HashMap<LogKind, usize>,
    /// Entries per still-indexed job (drives exact dead-entry accounting
    /// and the compaction retain predicate).
    per_job: HashMap<JobId, u32>,
    /// Entries in `entries` whose job was removed.
    dead: usize,
    /// Total pushes ever (monotone under pruning).
    appended: u64,
}

/// Every log-entry kind, for whole-job index removal.
const ALL_KINDS: [LogKind; 6] = [
    LogKind::Recognized,
    LogKind::DispatchDone,
    LogKind::Preempted,
    LogKind::Requeued,
    LogKind::Ended,
    LogKind::CronPreempted,
];

/// A scheduling-time measurement over a set of jobs (one submission burst).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SchedMeasurement {
    /// First `Recognized` among the jobs.
    pub first_recognized: SimTime,
    /// Last `DispatchDone` among the jobs.
    pub last_dispatched: SimTime,
    /// `last_dispatched - first_recognized` in seconds.
    pub total_secs: f64,
    /// Jobs that were recognized.
    pub jobs_recognized: usize,
    /// Jobs that completed dispatch.
    pub jobs_dispatched: usize,
}

impl SchedMeasurement {
    /// Seconds per task given the total task count of the burst.
    pub fn per_task(&self, tasks: u64) -> f64 {
        assert!(tasks > 0);
        self.total_secs / tasks as f64
    }
}

impl EventLog {
    /// Append an entry. Timestamps must be non-decreasing per job for the
    /// same kind; globally the log is in emission order.
    pub fn push(&mut self, time: SimTime, job: JobId, kind: LogKind) {
        self.entries.push(LogEntry { time, job, kind });
        self.first_idx.entry((job, kind)).or_insert(time);
        self.last_idx.insert((job, kind), time);
        *self.kind_counts.entry(kind).or_insert(0) += 1;
        *self.per_job.entry(job).or_insert(0) += 1;
        self.appended += 1;
    }

    /// All retained entries (pruned jobs' entries are gone; see
    /// [`EventLog::remove_job`]).
    pub fn entries(&self) -> &[LogEntry] {
        &self.entries
    }

    /// Total entries ever pushed — monotone even under pruning, which is
    /// what makes it a sound change-signature component (a pruned-then-
    /// regrown `entries().len()` could alias an old value).
    pub fn appended_total(&self) -> u64 {
        self.appended
    }

    /// Entries about one job.
    pub fn for_job(&self, job: JobId) -> impl Iterator<Item = &LogEntry> {
        self.entries.iter().filter(move |e| e.job == job)
    }

    /// Drop a retired job from the log: its first/last indexes go
    /// immediately, its entries are marked dead and reclaimed by the next
    /// half-dead compaction. Kind counts and [`EventLog::appended_total`]
    /// stay monotone. Callers must have frozen anything they still need
    /// (the daemon's history views) first.
    pub fn remove_job(&mut self, job: JobId) {
        let Some(n) = self.per_job.remove(&job) else {
            return; // never logged, or already removed
        };
        for kind in ALL_KINDS {
            self.first_idx.remove(&(job, kind));
            self.last_idx.remove(&(job, kind));
        }
        self.dead += n as usize;
        // Compact when at least half the vector is dead entries — O(live)
        // per compaction, amortized O(1) per entry over the log's life.
        if self.dead * 2 >= self.entries.len() && self.dead > 0 {
            let per_job = &self.per_job;
            self.entries.retain(|e| per_job.contains_key(&e.job));
            self.dead = 0;
        }
    }

    /// First entry of a kind for a job (O(1)).
    pub fn first(&self, job: JobId, kind: LogKind) -> Option<SimTime> {
        self.first_idx.get(&(job, kind)).copied()
    }

    /// Last entry of a kind for a job (O(1)).
    pub fn last(&self, job: JobId, kind: LogKind) -> Option<SimTime> {
        self.last_idx.get(&(job, kind)).copied()
    }

    /// Count of entries of a kind **ever logged** (across all jobs, O(1)).
    /// Monotone: pruning a retired job does not decrement it, so the WAIT
    /// completion generation derived from `count(Ended)` never runs
    /// backwards.
    pub fn count(&self, kind: LogKind) -> usize {
        self.kind_counts.get(&kind).copied().unwrap_or(0)
    }

    /// Measure the scheduling time of a burst of jobs, per the paper's
    /// definition. Returns `None` if none of the jobs were recognized or
    /// dispatched.
    pub fn measure(&self, jobs: &[JobId]) -> Option<SchedMeasurement> {
        let mut first_recognized: Option<SimTime> = None;
        let mut last_dispatched: Option<SimTime> = None;
        let mut nrec = 0usize;
        let mut ndis = 0usize;
        for &j in jobs {
            if let Some(t) = self.first(j, LogKind::Recognized) {
                nrec += 1;
                first_recognized = Some(first_recognized.map_or(t, |c: SimTime| c.min(t)));
            }
            if let Some(t) = self.last(j, LogKind::DispatchDone) {
                ndis += 1;
                last_dispatched = Some(last_dispatched.map_or(t, |c: SimTime| c.max(t)));
            }
        }
        let (fr, ld) = (first_recognized?, last_dispatched?);
        Some(SchedMeasurement {
            first_recognized: fr,
            last_dispatched: ld,
            total_secs: ld.saturating_sub(fr).as_secs_f64(),
            jobs_recognized: nrec,
            jobs_dispatched: ndis,
        })
    }

    /// Measure from an explicit start time (the paper's manual-preemption
    /// experiment measures "from the time when the preemption had started").
    pub fn measure_from(&self, start: SimTime, jobs: &[JobId]) -> Option<SchedMeasurement> {
        let m = self.measure(jobs)?;
        Some(SchedMeasurement {
            first_recognized: start,
            total_secs: m.last_dispatched.saturating_sub(start).as_secs_f64(),
            ..m
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_burst() {
        let mut log = EventLog::default();
        let (a, b) = (JobId(1), JobId(2));
        log.push(SimTime::from_secs(10), a, LogKind::Recognized);
        log.push(SimTime::from_secs(11), b, LogKind::Recognized);
        log.push(SimTime::from_secs(12), a, LogKind::DispatchDone);
        log.push(SimTime::from_secs(15), b, LogKind::DispatchDone);
        let m = log.measure(&[a, b]).unwrap();
        assert_eq!(m.first_recognized, SimTime::from_secs(10));
        assert_eq!(m.last_dispatched, SimTime::from_secs(15));
        assert_eq!(m.total_secs, 5.0);
        assert_eq!(m.jobs_dispatched, 2);
        assert_eq!(m.per_task(100), 0.05);
    }

    #[test]
    fn measure_missing_jobs_is_none() {
        let log = EventLog::default();
        assert!(log.measure(&[JobId(1)]).is_none());
    }

    #[test]
    fn requeued_job_uses_last_dispatch() {
        let mut log = EventLog::default();
        let j = JobId(1);
        log.push(SimTime::from_secs(1), j, LogKind::Recognized);
        log.push(SimTime::from_secs(2), j, LogKind::DispatchDone);
        log.push(SimTime::from_secs(3), j, LogKind::Preempted);
        log.push(SimTime::from_secs(9), j, LogKind::DispatchDone);
        let m = log.measure(&[j]).unwrap();
        assert_eq!(m.last_dispatched, SimTime::from_secs(9));
    }

    #[test]
    fn measure_from_start_overrides() {
        let mut log = EventLog::default();
        let j = JobId(1);
        log.push(SimTime::from_secs(5), j, LogKind::Recognized);
        log.push(SimTime::from_secs(8), j, LogKind::DispatchDone);
        let m = log.measure_from(SimTime::from_secs(2), &[j]).unwrap();
        assert_eq!(m.total_secs, 6.0);
    }

    #[test]
    fn remove_job_drops_indexes_and_compacts() {
        let mut log = EventLog::default();
        let (a, b) = (JobId(1), JobId(2));
        log.push(SimTime::from_secs(1), a, LogKind::Recognized);
        log.push(SimTime::from_secs(2), a, LogKind::DispatchDone);
        log.push(SimTime::from_secs(3), a, LogKind::Ended);
        log.push(SimTime::from_secs(4), b, LogKind::Recognized);
        assert_eq!(log.appended_total(), 4);
        log.remove_job(a);
        // Indexes answer nothing for the pruned job…
        assert!(log.first(a, LogKind::Recognized).is_none());
        assert!(log.last(a, LogKind::DispatchDone).is_none());
        assert!(log.measure(&[a]).is_none());
        // …while the survivor is untouched.
        assert_eq!(log.first(b, LogKind::Recognized), Some(SimTime::from_secs(4)));
        // 3 of 4 entries were dead → compaction ran.
        assert_eq!(log.entries().len(), 1);
        assert_eq!(log.entries()[0].job, b);
        // Monotone facts survive the prune.
        assert_eq!(log.appended_total(), 4);
        assert_eq!(log.count(LogKind::Ended), 1);
        assert_eq!(log.count(LogKind::Recognized), 2);
        // Removing twice (or an unknown job) is a no-op.
        log.remove_job(a);
        log.remove_job(JobId(99));
        assert_eq!(log.entries().len(), 1);
    }

    #[test]
    fn compaction_is_deferred_below_half_dead() {
        let mut log = EventLog::default();
        for i in 0..10u64 {
            log.push(SimTime::from_secs(i), JobId(i), LogKind::Recognized);
        }
        log.remove_job(JobId(0)); // 1/10 dead: no sweep yet
        assert_eq!(log.entries().len(), 10);
        for i in 1..5u64 {
            log.remove_job(JobId(i));
        }
        // 5/10 dead: compaction fires, only live jobs' entries remain.
        assert_eq!(log.entries().len(), 5);
        assert!(log.entries().iter().all(|e| e.job.0 >= 5));
        // Appends keep working after a compaction.
        log.push(SimTime::from_secs(99), JobId(42), LogKind::Recognized);
        assert_eq!(log.entries().len(), 6);
        assert_eq!(log.appended_total(), 11);
    }

    #[test]
    fn counts_by_kind() {
        let mut log = EventLog::default();
        log.push(SimTime::ZERO, JobId(1), LogKind::Preempted);
        log.push(SimTime::ZERO, JobId(2), LogKind::Preempted);
        log.push(SimTime::ZERO, JobId(1), LogKind::Requeued);
        assert_eq!(log.count(LogKind::Preempted), 2);
        assert_eq!(log.count(LogKind::Requeued), 1);
        assert_eq!(log.count(LogKind::Ended), 0);
    }

    #[test]
    fn wire_codes_roundtrip_and_are_dense() {
        for (i, kind) in ALL_KINDS.into_iter().enumerate() {
            let code = kind.wire_code();
            assert_eq!(code as usize, i, "codes are dense and stable");
            assert_eq!(LogKind::from_wire_code(code), Some(kind));
        }
        assert_eq!(LogKind::from_wire_code(ALL_KINDS.len() as u8), None);
    }
}
