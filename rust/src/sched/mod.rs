//! The `slurmlite` scheduler: pending queues, main + backfill scheduling
//! cycles, per-task dispatch, preemption hooks, and the event log the
//! paper's measurements read.
//!
//! The scheduler is a discrete-event model of a Slurm-class controller. The
//! control flow — *where preemption happens relative to allocation* — is
//! what the paper is about, and is modeled faithfully:
//!
//! * **Baseline**: a submission triggers a scheduling pass; jobs dispatch at
//!   per-task RPC cost (triple-mode jobs at per-node-script cost).
//! * **Auto preemption** ([`crate::preempt::auto`]): a blocked interactive
//!   job triggers candidate scan + requeue transactions *inside* the pass,
//!   and the job is then **deferred** for `auto_preempt_retry_cycles`
//!   scheduling cycles (Slurm re-examines preemptor jobs on later cycles) —
//!   this deferral is the 2–3 orders-of-magnitude degradation.
//! * **Manual / cron preemption**: the requeues happen *outside* the
//!   scheduler; an arriving interactive job finds idle nodes and dispatches
//!   at baseline cost.

pub mod config;
pub mod eventlog;
pub mod from_config;
pub mod priority;
pub mod queue;

pub use config::SchedulerConfig;
pub use from_config::{deployment_from_file, deployment_from_text, Deployment};
pub use eventlog::{EventLog, LogKind, SchedMeasurement};
pub use priority::{JobFactors, NativeScorer, PriorityScorer, N_FACTORS, WEIGHTS};

use crate::cluster::{AllocRequest, Cluster, NodeId, Partition, PartitionId};
use crate::job::{Job, JobId, JobSpec, JobState, QosClass, QosTable, UserAccounting};
use crate::preempt::{lua, PreemptApproach, PreemptMode};
use crate::sim::{EventQueue, SimTime};
use crate::util::fxhash::FxHashMap;
use crate::util::rng::Xoshiro256;
use queue::{OrderKey, PassOrder, PendingQueue};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

/// Memoized EASY-backfill end profile: the dispatch count it was built at
/// plus the sorted (end time, cores) release schedule of running jobs.
/// Shared across partitions within one scheduling pass and rebuilt only
/// when a dispatch changed the running set.
type EndProfile = Option<(u64, Vec<(SimTime, u64)>)>;

/// Scheduler events.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// A submitted job reaches the controller.
    JobArrival(JobId),
    /// Periodic main scheduling cycle.
    MainCycle,
    /// Periodic backfill cycle.
    BackfillCycle,
    /// Submit-/resource-triggered scheduling pass.
    Triggered,
    /// A requeue transaction finished; the victim re-enters the queue.
    RequeueFinish(JobId),
    /// Node epilog/cleanup finished; nodes become schedulable.
    EpilogDone(Vec<NodeId>),
    /// A running job completed.
    JobEnd(JobId),
    /// Cron-agent wake-up.
    CronTick,
}

/// Which flavor of scheduling pass is running.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CycleKind {
    /// Periodic main cycle (FIFO semantics: head-of-line blocking).
    Main,
    /// Periodic backfill cycle (scans past blocked jobs; heavier per-job).
    Backfill,
    /// Submit-/event-triggered pass (main-cycle semantics).
    Triggered,
}

/// Aggregate counters.
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    /// Main passes run.
    pub main_passes: u64,
    /// Backfill passes run.
    pub backfill_passes: u64,
    /// Triggered passes run.
    pub triggered_passes: u64,
    /// Jobs dispatched.
    pub dispatches: u64,
    /// Preemption victims (all approaches).
    pub preemptions: u64,
    /// Requeue transactions.
    pub requeues: u64,
    /// Cron agent passes.
    pub cron_passes: u64,
    /// Priority-scorer invocations (keys are computed incrementally at
    /// enqueue time, so this counts enqueue/requeue scorings, not per-pass
    /// whole-queue rescores).
    pub score_batches: u64,
    /// Factor rows scored across all scorer invocations.
    pub jobs_scored: u64,
}

/// The scheduler.
pub struct Scheduler {
    cfg: SchedulerConfig,
    cluster: Cluster,
    partitions: Vec<Partition>,
    jobs: BTreeMap<JobId, Job>,
    /// Per-partition pending queues: incrementally maintained priority
    /// order (per-user buckets of static keys merged under live fairshare
    /// offsets at pass time — see [`queue`]). O(log n) insert/remove,
    /// no global invalidation on fairshare changes.
    queues: BTreeMap<PartitionId, PendingQueue>,
    /// job → partition for O(1) queue removal (no per-partition scan).
    job_partition: FxHashMap<JobId, PartitionId>,
    /// Jobs deferred until a given time (auto-preempt retry, requeue hold).
    earliest_start: BTreeMap<JobId, SimTime>,
    /// Jobs for which auto-preemption was already requested.
    pub(crate) preempt_requested: BTreeSet<JobId>,
    /// Resources reserved for deferred preemptor jobs (cores). Spot jobs may
    /// not allocate into reserved headroom — Slurm guards the resources it
    /// freed by preemption for the preempting job the same way.
    reservations: BTreeMap<JobId, u32>,
    /// Aggregate of [`Scheduler::reservations`], maintained at
    /// reserve/dispatch/cancel so the pass loop reads it in O(1) instead of
    /// re-summing the table per examined spot job.
    reserved_pending_cores: u32,
    /// Currently suspended jobs (the resume path reads this instead of
    /// scanning the whole job table every pass).
    suspended: BTreeSet<JobId>,
    qos: QosTable,
    users: UserAccounting,
    clock: SimTime,
    events: EventQueue<Event>,
    log: EventLog,
    next_id: u64,
    /// Controller busy window (end of the last pass's virtual work).
    busy_until: SimTime,
    trigger_pending: bool,
    stats: SchedStats,
    /// Monotone change tick: bumped by every externally visible mutation
    /// (submission, event processing, cancel). The coordinator's published
    /// read snapshot uses it to skip re-capturing an unchanged scheduler.
    version: u64,
    /// Job-state mutations not reflected in job count or log length
    /// (suspend-resume); part of [`Scheduler::jobs_signature`].
    resumes: u64,
    /// Score gained per hour of queue age (probed from the scorer once at
    /// construction; see [`queue`] for why age folds into a static key).
    age_slope: f64,
    /// Score delta per unit of fairshare (probed once; applied as a
    /// per-user offset at pass time).
    share_slope: f64,
    /// Terminal jobs awaiting retirement, keyed by end time (min-heap).
    retire_heap: BinaryHeap<Reverse<(SimTime, JobId)>>,
    /// Terminal jobs removed by [`Scheduler::retire_terminal`] so far.
    retired_total: u64,
    /// Memo of the age-0/share-0 score per (qos, cores, requeue_count) —
    /// the only inputs the static factor row depends on. A burst of N
    /// identical individual jobs costs one scorer invocation, not N, which
    /// keeps the batched XLA scorer viable on the enqueue path.
    key_score_cache: FxHashMap<(QosClass, u32, u32), f32>,
    /// Reusable pass-order merge state: at high user cardinality the k-way
    /// heap reaches millions of entries, so passes refill this one
    /// allocation (O(u) heapify) instead of growing a fresh heap each time.
    pass_order_scratch: PassOrder,
}

impl Scheduler {
    /// Create a scheduler over `cluster` with the given configuration.
    /// Periodic cycles (and the cron agent, when configured) start at a
    /// seed-dependent phase within their periods.
    pub fn new(cluster: Cluster, cfg: SchedulerConfig) -> Self {
        let partitions = cfg.layout.partitions();
        let mut queues = BTreeMap::new();
        for p in &partitions {
            queues.insert(p.id, PendingQueue::default());
        }
        // Probe the scorer's age and fairshare slopes once: the incremental
        // queue assumes the score is affine in both factors (true for the
        // native dot product and the XLA matvec kernel), which lets age
        // fold into a time-invariant static key and fairshare into a
        // per-user offset.
        let mut age_row = [0.0f32; N_FACTORS];
        age_row[1] = 1.0;
        let mut share_row = [0.0f32; N_FACTORS];
        share_row[5] = 1.0;
        let probes = cfg.scorer.scores(&[
            JobFactors([0.0f32; N_FACTORS]),
            JobFactors(age_row),
            JobFactors(share_row),
        ]);
        let age_slope = (probes[1] - probes[0]) as f64;
        let share_slope = (probes[2] - probes[0]) as f64;
        let mut rng = Xoshiro256::new(cfg.phase_seed);
        let mut events = EventQueue::new();
        let main_phase = SimTime(rng.gen_range(1, cfg.costs.main_cycle_period.0.max(2)));
        let bf_phase = SimTime(rng.gen_range(1, cfg.costs.backfill_cycle_period.0.max(2)));
        events.push(main_phase, Event::MainCycle);
        events.push(bf_phase, Event::BackfillCycle);

        let mut qos = QosTable::new();
        let users = UserAccounting::with_default_limit(cfg.user_core_limit);

        if let PreemptApproach::CronAgent { cfg: ccfg, .. } = &cfg.approach {
            // The agent installs the initial spot ceiling at deployment so
            // spot jobs can never consume the reserve.
            let reserve_cores = ccfg.reserve_nodes * cluster.cores_per_node();
            let cap = cluster.total_cores().saturating_sub(reserve_cores);
            qos.config_mut(QosClass::Spot).max_tres_total = Some(cap);
            qos.config_mut(QosClass::Spot).max_tres_per_user = Some(cap);
            let cron_phase = SimTime(rng.gen_range(1, cfg.costs.cron_interval.0.max(2)));
            events.push(cron_phase, Event::CronTick);
        }

        Self {
            cfg,
            cluster,
            partitions,
            jobs: BTreeMap::new(),
            queues,
            job_partition: FxHashMap::default(),
            earliest_start: BTreeMap::new(),
            preempt_requested: BTreeSet::new(),
            reservations: BTreeMap::new(),
            reserved_pending_cores: 0,
            suspended: BTreeSet::new(),
            qos,
            users,
            clock: SimTime::ZERO,
            events,
            log: EventLog::default(),
            next_id: 1,
            busy_until: SimTime::ZERO,
            trigger_pending: false,
            stats: SchedStats::default(),
            version: 0,
            resumes: 0,
            age_slope,
            share_slope,
            retire_heap: BinaryHeap::new(),
            retired_total: 0,
            key_score_cache: FxHashMap::default(),
            pass_order_scratch: PassOrder::default(),
        }
    }

    // ---- accessors --------------------------------------------------------

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.clock
    }

    /// The cluster.
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Mutate the cluster for failure-injection tests (e.g. drain a node).
    pub fn cluster_mut_for_tests(&mut self, f: impl FnOnce(&mut Cluster)) {
        self.version += 1;
        f(&mut self.cluster)
    }

    /// Monotone change tick (see the `version` field): equal ticks guarantee
    /// an identical job table, queue contents, counters, and cluster
    /// occupancy. The clock may still have advanced.
    pub fn change_version(&self) -> u64 {
        self.version
    }

    /// User-cardinality gauges, O(partitions) to read: `(active, tracked)`
    /// where *active* counts fairshare-table entries with nonzero charged
    /// usage (normal + per-qos) and *tracked* additionally counts live
    /// pending-queue (qos, user) buckets. Both tables retire entries at
    /// zero, so these measure current load — a million-user submission
    /// history that has drained reads as (0, 0).
    pub fn user_scale(&self) -> (usize, usize) {
        let active = self.users.tracked() + self.qos.tracked();
        let queued: usize = self.queues.values().map(|q| q.bucket_count()).sum();
        (active, active + queued)
    }

    /// O(1) signature of the externally visible **job table**: job states,
    /// membership (including retirement — `next_id` covers additions, the
    /// table length covers removals), and event-log-derived fields cannot
    /// change without it moving (every transition either logs an entry,
    /// adds a job, or bumps the resume counter). Counters and cluster
    /// occupancy are *not* covered — equal signatures across e.g. an empty
    /// scheduling pass let the coordinator share the previous snapshot's
    /// job table instead of rebuilding it. The log component is
    /// [`EventLog::appended_total`], not the retained length: pruning
    /// shrinks the vector, and a shrunk-then-regrown length could alias an
    /// old signature and serve a stale table.
    pub fn jobs_signature(&self) -> (usize, u64, u64, u64) {
        (self.jobs.len(), self.next_id, self.log.appended_total(), self.resumes)
    }

    /// All job records, in ascending id order.
    pub fn jobs(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values()
    }

    /// The event log.
    pub fn log(&self) -> &EventLog {
        &self.log
    }

    /// Counters.
    pub fn stats(&self) -> &SchedStats {
        &self.stats
    }

    /// Configuration.
    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Job record.
    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(&id)
    }

    /// All jobs in a given state.
    pub fn jobs_in_state(&self, state: JobState) -> Vec<JobId> {
        self.jobs
            .iter()
            .filter(|(_, j)| j.state == state)
            .map(|(&id, _)| id)
            .collect()
    }

    /// Running spot jobs (preemption candidates), as LIFO victim records.
    /// Walks the cluster's allocation table — bounded by what actually
    /// runs — instead of the whole job history.
    pub fn spot_victims(&self) -> Vec<crate::preempt::lifo::Victim> {
        let cores_per_node = self.cluster.cores_per_node();
        self.cluster
            .allocations()
            .filter_map(|(id, alloc)| {
                let j = self.jobs.get(&id)?;
                if !j.is_spot() || j.state != JobState::Running {
                    return None;
                }
                let whole_nodes = alloc
                    .slices
                    .iter()
                    .filter(|&&(_, c)| c == cores_per_node)
                    .count() as u32;
                Some(crate::preempt::lifo::Victim {
                    job: j.id,
                    queue_time: j.queue_time,
                    cores: alloc.cores(),
                    whole_nodes,
                })
            })
            .collect()
    }

    /// Terminal jobs removed from the job table by
    /// [`Scheduler::retire_terminal`] so far.
    pub fn retired_total(&self) -> u64 {
        self.retired_total
    }

    /// Remove terminal jobs whose end time lies more than `grace` in the
    /// past and return their records (the coordinator moves them into its
    /// history side-table). Bounds the job table — and with it snapshot
    /// capture — for long-lived daemons. O(retired · log pending-retires).
    pub fn retire_terminal(&mut self, grace: SimTime) -> Vec<Job> {
        let mut out = Vec::new();
        while let Some(&Reverse((end, id))) = self.retire_heap.peek() {
            if SimTime(end.0.saturating_add(grace.0)) > self.clock {
                break;
            }
            self.retire_heap.pop();
            let job = self.jobs.remove(&id).expect("retire heap holds live terminal jobs");
            debug_assert!(job.state.is_terminal());
            out.push(job);
        }
        if !out.is_empty() {
            self.version += 1;
            self.retired_total += out.len() as u64;
        }
        out
    }

    /// Drop retired jobs' event-log entries (indexes immediately, storage
    /// via the log's amortized half-dead compaction). Callers freeze any
    /// views they still need *before* this — afterwards the log answers
    /// nothing for these ids. Pruning is invisible to the change signature
    /// ([`Scheduler::jobs_signature`] keys on the monotone append total)
    /// and to the WAIT generation (kind counts stay monotone).
    pub fn prune_retired_log(&mut self, ids: impl IntoIterator<Item = JobId>) {
        for id in ids {
            self.log.remove_job(id);
        }
    }

    /// QoS table (read access for tests and the experiments harness).
    pub fn qos(&self) -> &QosTable {
        &self.qos
    }

    // ---- submission --------------------------------------------------------

    /// Submit one job now. The scheduler recognizes it after the submit RPC.
    pub fn submit(&mut self, spec: JobSpec) -> JobId {
        self.submit_after(spec, SimTime::ZERO)
    }

    /// Submit one job with an extra client-side delay before the RPC lands.
    pub fn submit_after(&mut self, spec: JobSpec, delay: SimTime) -> JobId {
        self.version += 1;
        let id = JobId(self.next_id);
        self.next_id += 1;
        let arrive = self.clock + delay + self.cfg.costs.submit_rpc;
        let job = Job::new(id, spec, arrive);
        self.jobs.insert(id, job);
        self.events.push(arrive, Event::JobArrival(id));
        id
    }

    /// Submit a burst of jobs from one client loop: submissions serialize on
    /// the client side, one `submit_rpc` apart (how the paper's launcher
    /// fills a cluster with individual jobs).
    pub fn submit_burst(&mut self, specs: Vec<JobSpec>) -> Vec<JobId> {
        specs
            .into_iter()
            .enumerate()
            .map(|(i, s)| self.submit_after(s, SimTime(self.cfg.costs.submit_rpc.0 * i as u64)))
            .collect()
    }

    /// Submit many jobs arriving in **one** batched RPC: the whole set pays
    /// a single `submit_rpc` and reaches the controller at the same virtual
    /// instant. This is the batch-manifest submission path the coordinator's
    /// `SUBMIT ... count=N` exposes (vs. [`Scheduler::submit_burst`], which
    /// models a client loop issuing one RPC per job).
    pub fn submit_batch(&mut self, specs: Vec<JobSpec>) -> Vec<JobId> {
        specs
            .into_iter()
            .map(|s| self.submit_after(s, SimTime::ZERO))
            .collect()
    }

    /// Force the id counter forward (never backwards) — crash recovery
    /// uses this to cover ids a checkpoint proves were assigned even when
    /// no live job or tail record reproduces them (retired jobs).
    pub fn force_next_id(&mut self, next: u64) {
        self.version += 1;
        self.next_id = self.next_id.max(next);
    }

    /// Re-insert a checkpointed job during crash recovery. The job comes
    /// back Pending with its *original* submit/queue time (so its age
    /// priority is preserved) and requeue count, its pre-crash event-log
    /// entries are restored (so `SJOB` still reports its history), and an
    /// arrival event is queued at `arrive_at` — the caller then runs the
    /// clock forward and the normal admission path re-recognizes and
    /// re-dispatches it, exactly like a preempted-and-requeued job.
    pub fn restore_job(
        &mut self,
        id: JobId,
        spec: JobSpec,
        submit_time: SimTime,
        requeue_count: u32,
        log_entries: &[(SimTime, LogKind)],
        arrive_at: SimTime,
    ) {
        debug_assert!(!self.jobs.contains_key(&id), "restore of a live id");
        self.version += 1;
        self.next_id = self.next_id.max(id.0 + 1);
        let mut job = Job::new(id, spec, submit_time);
        job.requeue_count = requeue_count;
        self.jobs.insert(id, job);
        for &(t, kind) in log_entries {
            self.log.push(t, id, kind);
        }
        self.events.push(arrive_at.max(self.clock), Event::JobArrival(id));
    }

    // ---- event loop --------------------------------------------------------

    /// Process events up to and including `until`, then advance the clock to
    /// `until`.
    pub fn run_until(&mut self, until: SimTime) {
        while let Some(t) = self.events.peek_time() {
            if t > until {
                break;
            }
            let (t, ev) = self.events.pop().expect("peeked");
            debug_assert!(t >= self.clock);
            self.clock = t;
            self.handle(ev);
        }
        if until > self.clock {
            self.clock = until;
        }
    }

    /// Run for a duration from now.
    pub fn run_for(&mut self, d: SimTime) {
        self.run_until(self.clock + d);
    }

    /// Run until every job in `jobs` has dispatched or `timeout` elapses
    /// (relative to now). Returns true when all dispatched.
    ///
    /// Event-driven: steps the clock to the next queued event time instead
    /// of fixed 1-second increments, so a large burst pays one pass per
    /// event batch rather than a wall of empty polls.
    pub fn run_until_dispatched(&mut self, jobs: &[JobId], timeout: SimTime) -> bool {
        let horizon = self.clock + timeout;
        // Keep the set of jobs not yet seen dispatched, and settle it by
        // consuming *newly appended* `DispatchDone` log entries after each
        // event batch. Total polling cost is O(jobs · log jobs + new log
        // entries) — a per-batch rescan of the remaining set was quadratic
        // on a 100k burst (and stayed quadratic under trickle dispatches
        // even when gated on the dispatch counter).
        let mut remaining: BTreeSet<JobId> = jobs
            .iter()
            .copied()
            .filter(|&j| self.log.last(j, LogKind::DispatchDone).is_none())
            .collect();
        let mut log_pos = self.log.entries().len();
        loop {
            if remaining.is_empty() {
                return true;
            }
            match self.events.peek_time() {
                // Process the whole event batch at the next event time (plus
                // anything it schedules at that same instant).
                Some(t) if t <= horizon => self.run_until(t),
                // No more events before the horizon: nothing left can
                // dispatch within the timeout.
                _ => break,
            }
            let entries = self.log.entries();
            for e in &entries[log_pos..] {
                if e.kind == LogKind::DispatchDone {
                    remaining.remove(&e.job);
                }
            }
            log_pos = entries.len();
        }
        self.run_until(horizon);
        for e in &self.log.entries()[log_pos..] {
            if e.kind == LogKind::DispatchDone {
                remaining.remove(&e.job);
            }
        }
        remaining.is_empty()
    }

    fn handle(&mut self, ev: Event) {
        self.version += 1;
        match ev {
            Event::JobArrival(id) => self.on_arrival(id),
            Event::MainCycle => self.on_periodic(CycleKind::Main),
            Event::BackfillCycle => self.on_periodic(CycleKind::Backfill),
            Event::Triggered => {
                self.trigger_pending = false;
                if self.clock < self.busy_until {
                    // Controller busy; re-run when it frees up.
                    self.request_trigger(self.busy_until);
                } else {
                    self.stats.triggered_passes += 1;
                    self.run_pass(CycleKind::Triggered);
                }
            }
            Event::RequeueFinish(id) => self.on_requeue_finish(id),
            Event::EpilogDone(nodes) => self.on_epilog_done(nodes),
            Event::JobEnd(id) => self.on_job_end(id),
            Event::CronTick => self.on_cron_tick(),
        }
    }

    fn on_arrival(&mut self, id: JobId) {
        // The job may have been cancelled between the submit RPC and the
        // controller recognizing it (and, under an aggressive retirement
        // grace, even retired already); a stale arrival must not re-queue
        // it or assume the record still exists. An unknown id with no
        // retirement in play is a scheduler bug and must stay loud.
        let Some(job) = self.jobs.get(&id) else {
            debug_assert!(self.retired_total > 0, "arrival for unknown job {id}");
            return;
        };
        if job.state != JobState::Pending {
            return;
        }
        self.log.push(self.clock, id, LogKind::Recognized);
        // The Recognized record materializes a job-view field without a
        // state transition: bump the per-job revision by hand so snapshot
        // delta capture rebuilds this job's view.
        self.jobs.get_mut(&id).expect("arrival for unknown job").touch();
        if self.cfg.lua_plugin {
            // The paper's Lua job_submit attempt: the plugin observes the
            // submission but cannot execute scheduler commands.
            let mut gate = lua::DenyAllGate;
            let outcome = lua::LuaSubmitPlugin.job_submit(
                self.jobs.get(&id).expect("arrival for unknown job"),
                &mut gate,
            );
            debug_assert!(outcome.preempt_attempt.is_err());
        }
        let job = self.jobs.get(&id).expect("arrival for unknown job");
        let pid = self.cfg.layout.route(job.spec.qos);
        self.push_pending(pid, id);
        // Submit-triggered scheduling pass.
        let at = (self.clock + self.cfg.costs.submit_trigger_delay).max(self.busy_until);
        self.request_trigger(at);
    }

    fn on_periodic(&mut self, kind: CycleKind) {
        let period = match kind {
            CycleKind::Main => self.cfg.costs.main_cycle_period,
            CycleKind::Backfill => self.cfg.costs.backfill_cycle_period,
            CycleKind::Triggered => unreachable!(),
        };
        // Re-arm first so an overrunning pass cannot cancel the cycle.
        let next = self.clock.next_boundary(period);
        self.events.push(
            next,
            match kind {
                CycleKind::Main => Event::MainCycle,
                CycleKind::Backfill => Event::BackfillCycle,
                CycleKind::Triggered => unreachable!(),
            },
        );
        if self.clock < self.busy_until {
            // Controller still busy with a previous pass: skip (Slurm defers
            // overlapping cycles).
            return;
        }
        match kind {
            CycleKind::Main => self.stats.main_passes += 1,
            CycleKind::Backfill => self.stats.backfill_passes += 1,
            CycleKind::Triggered => unreachable!(),
        }
        self.run_pass(kind);
    }

    /// Request a triggered pass at time `at` (coalesced).
    pub(crate) fn request_trigger(&mut self, at: SimTime) {
        if self.trigger_pending {
            return;
        }
        self.trigger_pending = true;
        self.events.push(at.max(self.clock), Event::Triggered);
    }

    // ---- the scheduling pass ----------------------------------------------

    fn pass_base_cost(&self, kind: CycleKind) -> SimTime {
        let c = &self.cfg.costs;
        match kind {
            CycleKind::Main | CycleKind::Triggered => {
                SimTime(c.main_per_job.0 * c.background_queue_depth as u64)
            }
            CycleKind::Backfill => SimTime(
                c.backfill_pass_base.0 + c.backfill_per_job.0 * c.background_queue_depth as u64,
            ),
        }
    }

    /// EASY-backfill shadow time: the earliest time the blocked head job
    /// could start, assuming currently-running jobs end on schedule
    /// (start + run_time) and release their cores. `None` = never (the job
    /// cannot be satisfied by waiting — e.g. it is larger than the
    /// cluster), in which case backfill is unrestricted.
    ///
    /// The sorted release schedule (`memo`) is memoized across the whole
    /// pass — both partitions reuse it — and rebuilt only when a dispatch
    /// changed the running set, the only in-pass allocation mutation.
    fn shadow_start_for(&self, head: JobId, memo: &mut EndProfile) -> Option<SimTime> {
        let cores_per_node = self.cluster.cores_per_node();
        let need = self.jobs[&head]
            .spec
            .alloc_request(cores_per_node)
            .cores_on(&self.cluster) as u64;
        let mut avail = self.cluster.idle_cores() as u64;
        if avail >= need {
            return Some(self.clock);
        }
        let fresh = matches!(memo, Some((d, _)) if *d == self.stats.dispatches);
        if !fresh {
            let mut ends: Vec<(SimTime, u64)> = self
                .cluster
                .allocations()
                .filter_map(|(id, alloc)| {
                    let j = self.jobs.get(&id)?;
                    let start = j.start_time?;
                    Some((start + j.spec.run_time, alloc.cores() as u64))
                })
                .collect();
            ends.sort();
            *memo = Some((self.stats.dispatches, ends));
        }
        for &(t, c) in &memo.as_ref().expect("just built").1 {
            avail += c;
            if avail >= need {
                return Some(t);
            }
        }
        None
    }

    fn run_pass(&mut self, kind: CycleKind) {
        let mut cursor = self.clock + self.pass_base_cost(kind);
        let per_job_cost = match kind {
            CycleKind::Main | CycleKind::Triggered => self.cfg.costs.main_per_job,
            CycleKind::Backfill => self.cfg.costs.backfill_per_job,
        };
        // Backfill examines at most bf_max_job_test candidates per pass
        // (Slurm's knob of the same name) — an unbounded scan over a
        // 100k-deep queue would dominate both virtual and wall time.
        let scan_limit = match kind {
            CycleKind::Backfill => self.cfg.costs.bf_max_job_test,
            CycleKind::Main | CycleKind::Triggered => usize::MAX,
        };
        // EASY shadow release schedule, shared across partitions this pass.
        let mut end_profile: EndProfile = None;
        // The backfill candidate budget is per *pass*, shared across
        // partitions (matching the SchedCosts::bf_max_job_test contract).
        let mut examined = 0usize;
        let partition_ids: Vec<PartitionId> = self.partitions.iter().map(|p| p.id).collect();
        // Borrow the reusable merge state for the duration of the pass; it
        // is refilled per partition and handed back (with its capacity)
        // below.
        let mut order = std::mem::take(&mut self.pass_order_scratch);
        for pid in partition_ids {
            // EASY backfill: once a Normal job blocks, later candidates may
            // only start if they finish before the head's shadow time.
            let mut shadow: Option<Option<SimTime>> = None; // Some(reservation) once a head blocked
            // The frozen pass order: a lazy merge over the partition's user
            // buckets with fairshare offsets read once at pass start (the
            // pass's own dispatches change fairshare for the *next* pass,
            // exactly like the old cached order).
            {
                let q = self.queues.get(&pid).expect("partition");
                let users = &self.users;
                let qos_table = &self.qos;
                let total = self.cluster.total_cores().max(1) as f64;
                let slope = self.share_slope;
                order.rebuild(q, |qos, user| {
                    let usage = match qos {
                        QosClass::Normal => users.usage(user),
                        QosClass::Spot => qos_table.usage(QosClass::Spot, user),
                    } as f64;
                    slope * (usage / total).clamp(0.0, 1.0)
                });
            }
            loop {
                if examined >= scan_limit {
                    break;
                }
                let next = {
                    let q = self.queues.get(&pid).expect("partition");
                    order.next(q)
                };
                let Some(id) = next else { break };
                examined += 1;
                cursor += per_job_cost;
                // Deferred jobs (requeue hold / auto-preempt retry) are
                // ineligible: skipped, not blocking.
                if self.earliest_start.get(&id).is_some_and(|&t| t > self.clock) {
                    continue;
                }
                let job = &self.jobs[&id];
                let spec = job.spec.clone();
                let req = spec.alloc_request(self.cluster.cores_per_node());
                let need_cores = req.cores_on(&self.cluster);
                // Admission: per-user interactive limit / spot QoS caps.
                let admitted = match spec.qos {
                    QosClass::Normal => self.users.admits(spec.user, need_cores),
                    QosClass::Spot => self.qos.admits(QosClass::Spot, spec.user, need_cores),
                };
                if !admitted {
                    continue;
                }
                // Spot jobs may not consume headroom reserved for deferred
                // preemptor jobs (the aggregate counter is maintained at
                // reserve/dispatch/cancel — reservations only ever belong
                // to pending jobs).
                if spec.qos == QosClass::Spot {
                    let reserved = self.reserved_pending_cores;
                    if reserved > 0
                        && self.cluster.idle_cores() < need_cores.saturating_add(reserved)
                    {
                        continue;
                    }
                }
                if self.cluster.can_allocate(req) {
                    // Backfill candidates must not delay the blocked head
                    // job's reserved start (EASY backfill).
                    if kind == CycleKind::Backfill {
                        if let Some(Some(resv)) = shadow {
                            let ends_at = cursor + self.jobs[&id].spec.run_time;
                            if ends_at > resv {
                                continue;
                            }
                        }
                    }
                    cursor = self.dispatch(id, req, cursor);
                } else {
                    // Blocked. Auto preemption (if configured) fires here —
                    // inside the allocation path, exactly where Slurm's
                    // QoS preemption sits.
                    if spec.qos == QosClass::Normal {
                        if let PreemptApproach::AutoScheduler { mode } = self.cfg.approach {
                            if !self.preempt_requested.contains(&id)
                                && kind != CycleKind::Backfill
                            {
                                cursor = self.auto_preempt_for(id, req, mode, cursor);
                            }
                        }
                        if matches!(kind, CycleKind::Main | CycleKind::Triggered) {
                            // FIFO head-of-line: the main cycle stops at the
                            // first blocked normal job in a partition.
                            break;
                        }
                        // Backfill: the first blocked Normal job becomes the
                        // head; compute its shadow reservation once.
                        if shadow.is_none() {
                            shadow = Some(self.shadow_start_for(id, &mut end_profile));
                        }
                    }
                    // Backfill continues past blocked jobs.
                }
            }
        }
        self.pass_order_scratch = order;
        // Resume suspended spot jobs once no interactive demand is pending
        // (their allocations were never released — SUSPEND holds memory).
        // The suspended set and per-queue Normal counters make the common
        // "nothing suspended" case O(1) instead of a job-table scan.
        if !self.suspended.is_empty() {
            let any_pending_normal = self.queues.values().any(|q| q.normal_pending() > 0);
            if !any_pending_normal {
                for id in std::mem::take(&mut self.suspended) {
                    cursor += self.cfg.costs.requeue_transaction; // resume RPC
                    self.resumes += 1; // not logged: keep jobs_signature honest
                    let job = self.jobs.get_mut(&id).expect("suspended job");
                    job.transition(JobState::Running, cursor);
                    let run = job.spec.run_time;
                    self.events.push(cursor + run, Event::JobEnd(id));
                }
            }
        }
        self.busy_until = self.busy_until.max(cursor);
    }

    /// Static priority key for a newly queued job: its score at age 0 with
    /// zero fairshare, shifted by the age slope times its queue time so any
    /// two keys compare exactly like the live (uncapped-age) scores do.
    ///
    /// The age-0 score depends only on (qos, cores, requeue_count), so it
    /// is memoized — a burst of identical specs pays the scorer once.
    fn static_key(&mut self, id: JobId) -> OrderKey {
        let j = &self.jobs[&id];
        let cache_key = (j.spec.qos, j.spec.cores(), j.requeue_count);
        let qt_hours = j.queue_time.as_secs_f64() / 3600.0;
        let base = match self.key_score_cache.get(&cache_key).copied() {
            Some(s) => s,
            None => {
                let j = &self.jobs[&id];
                let qp = self.qos.config(j.spec.qos).priority;
                let f = JobFactors::of(j, qp, 0, 0.0, j.queue_time);
                let s = self.cfg.scorer.scores(std::slice::from_ref(&f))[0];
                self.stats.score_batches += 1;
                self.stats.jobs_scored += 1;
                self.key_score_cache.insert(cache_key, s);
                s
            }
        };
        OrderKey::of_score(base as f64 - self.age_slope * qt_hours)
    }

    /// Dispatch a pending job: allocate, charge accounting, emit dispatch
    /// RPCs (advancing `cursor` by the per-task cost), log, schedule its end.
    fn dispatch(&mut self, id: JobId, req: AllocRequest, mut cursor: SimTime) -> SimTime {
        let alloc = self
            .cluster
            .allocate(id, req)
            .expect("dispatch called after can_allocate");
        let cores = alloc.cores();
        let (user, qos, run_time, dispatches, is_triple) = {
            let j = &self.jobs[&id];
            (
                j.spec.user,
                j.spec.qos,
                j.spec.run_time,
                j.spec.dispatch_count(self.cluster.cores_per_node()),
                j.spec.job_type == crate::job::JobType::TripleMode,
            )
        };
        match qos {
            QosClass::Normal => self.users.charge(user, cores),
            QosClass::Spot => self.qos.charge(QosClass::Spot, user, cores),
        }
        // Usage changed — no cache to invalidate: pass orders read live
        // fairshare offsets per user bucket when they are built.
        cursor += self.cfg.costs.dispatch_cost(dispatches, is_triple);
        if is_triple {
            cursor += self.cfg.costs.triple_mode_setup;
        }
        let job = self.jobs.get_mut(&id).expect("dispatching unknown job");
        job.transition(JobState::Running, cursor);
        self.log.push(cursor, id, LogKind::DispatchDone);
        self.remove_from_pending(id);
        self.earliest_start.remove(&id);
        self.preempt_requested.remove(&id);
        self.clear_reservation(id);
        self.events.push(cursor + run_time, Event::JobEnd(id));
        self.stats.dispatches += 1;
        cursor
    }

    /// Drop a job from its partition's pending queue: O(log n) via the
    /// job→partition index (no scan over partitions or queue positions).
    fn remove_from_pending(&mut self, id: JobId) {
        if let Some(pid) = self.job_partition.remove(&id) {
            self.queues.get_mut(&pid).expect("partition").remove(id);
        }
    }

    /// Queue a job into its partition's pending queue under a freshly
    /// computed static priority key.
    fn push_pending(&mut self, pid: PartitionId, id: JobId) {
        let key = self.static_key(id);
        let (qos, user) = {
            let j = &self.jobs[&id];
            (j.spec.qos, j.spec.user)
        };
        self.queues.get_mut(&pid).expect("partition").insert(id, qos, user, key);
        self.job_partition.insert(id, pid);
    }

    /// Drop a job's headroom reservation, keeping the aggregate counter in
    /// sync.
    fn clear_reservation(&mut self, id: JobId) {
        if let Some(cores) = self.reservations.remove(&id) {
            self.reserved_pending_cores -= cores;
        }
    }

    /// Record a terminal transition for later retirement.
    fn mark_terminal(&mut self, id: JobId, at: SimTime) {
        self.retire_heap.push(Reverse((at, id)));
    }

    // ---- preemption plumbing (shared by auto / manual / cron) -------------

    /// Issue preemption of `victims` (in order) starting at `start`,
    /// serializing one requeue transaction per victim. Returns the time the
    /// last transaction completed. Resources are released immediately but
    /// nodes stay in cleanup until the epilog completes.
    pub(crate) fn issue_preemption(
        &mut self,
        victims: &[JobId],
        mode: PreemptMode,
        start: SimTime,
        by_cron: bool,
    ) -> SimTime {
        let mut cursor = start.max(self.clock);
        for &v in victims {
            cursor += self.cfg.costs.requeue_transaction;
            self.stats.preemptions += 1;
            self.log.push(
                cursor,
                v,
                if by_cron {
                    LogKind::CronPreempted
                } else {
                    LogKind::Preempted
                },
            );
            let (user, qos) = {
                let j = &self.jobs[&v];
                (j.spec.user, j.spec.qos)
            };
            match mode {
                PreemptMode::Requeue | PreemptMode::Cancel => {
                    let alloc = self
                        .cluster
                        .release(v)
                        .expect("preempting a job without an allocation");
                    match qos {
                        QosClass::Normal => self.users.credit(user, alloc.cores()),
                        QosClass::Spot => self.qos.credit(QosClass::Spot, user, alloc.cores()),
                    }
                    let nodes: Vec<NodeId> = alloc.slices.iter().map(|&(n, _)| n).collect();
                    for &n in &nodes {
                        self.cluster_node_mut(n).begin_cleanup();
                    }
                    self.events
                        .push(cursor + self.cfg.costs.node_epilog, Event::EpilogDone(nodes));
                    let job = self.jobs.get_mut(&v).expect("victim");
                    if mode == PreemptMode::Requeue {
                        job.transition(JobState::Requeued, cursor);
                        self.stats.requeues += 1;
                        self.events.push(cursor, Event::RequeueFinish(v));
                    } else {
                        job.transition(JobState::Cancelled, cursor);
                        self.log.push(cursor, v, LogKind::Ended);
                        self.mark_terminal(v, cursor);
                    }
                }
                PreemptMode::Suspend => {
                    // Memory is NOT freed: the allocation stays, so the node
                    // cannot serve an interactive job that needs full memory.
                    // This is exactly why the paper rejects SUSPEND.
                    let job = self.jobs.get_mut(&v).expect("victim");
                    job.transition(JobState::Suspended, cursor);
                    self.suspended.insert(v);
                }
                PreemptMode::Gang => {
                    panic!(
                        "GANG preemption timeshares resources and is rejected by the \
                         paper's requirements; the engine does not implement it"
                    );
                }
            }
        }
        cursor
    }

    fn cluster_node_mut(&mut self, id: NodeId) -> &mut crate::cluster::Node {
        self.cluster.node_mut(id)
    }

    /// Defer a job until `at` (auto-preempt retry / requeue hold).
    pub(crate) fn defer_until(&mut self, id: JobId, at: SimTime) {
        self.earliest_start.insert(id, at);
    }

    /// Reserve `cores` of headroom for a deferred preemptor job: spot jobs
    /// cannot allocate into it until the job dispatches or is cancelled.
    pub(crate) fn reserve_for(&mut self, id: JobId, cores: u32) {
        let prev = self.reservations.insert(id, cores).unwrap_or(0);
        self.reserved_pending_cores = self.reserved_pending_cores + cores - prev;
    }

    fn on_requeue_finish(&mut self, id: JobId) {
        let hold = self.cfg.requeue_hold;
        // Tolerate a record retired between the requeue and this event
        // (cancelled-then-retired under a short grace period); anything
        // else unknown is a scheduler bug and must stay loud.
        let Some(job) = self.jobs.get_mut(&id) else {
            debug_assert!(self.retired_total > 0, "requeue of unknown job {id}");
            return;
        };
        if job.state != JobState::Requeued {
            return; // cancelled in between
        }
        job.transition(JobState::Pending, self.clock);
        self.log.push(self.clock, id, LogKind::Requeued);
        let qos = self.jobs[&id].spec.qos;
        let pid = self.cfg.layout.route(qos);
        self.push_pending(pid, id);
        self.defer_until(id, self.clock + hold);
    }

    fn on_epilog_done(&mut self, nodes: Vec<NodeId>) {
        for n in nodes {
            self.cluster_node_mut(n).end_cleanup();
        }
        if self.cfg.event_driven {
            let at = self.clock.max(self.busy_until);
            self.request_trigger(at);
        }
    }

    fn on_job_end(&mut self, id: JobId) {
        // A cancelled job keeps its scheduled JobEnd in the event queue; if
        // its record was retired before that stale event fires, there is
        // nothing to do (panicking here would kill a long-lived daemon).
        // With no retirement in play an unknown id is a scheduler bug and
        // must stay loud (the seed's fail-loud-in-simulation contract).
        let Some(job) = self.jobs.get_mut(&id) else {
            debug_assert!(self.retired_total > 0, "end of unknown job {id}");
            return;
        };
        if job.state != JobState::Running {
            return; // was preempted or cancelled before its natural end
        }
        // Stale-event guard: a suspended/requeued-and-restarted job carries
        // the JobEnd of its *previous* run; only the run that has actually
        // elapsed completes the job.
        if let Some(start) = job.start_time {
            if self.clock < start + job.spec.run_time {
                return;
            }
        }
        job.transition(JobState::Completed, self.clock);
        let (user, qos) = (job.spec.user, job.spec.qos);
        self.log.push(self.clock, id, LogKind::Ended);
        self.mark_terminal(id, self.clock);
        if let Some(alloc) = self.cluster.release(id) {
            match qos {
                QosClass::Normal => self.users.credit(user, alloc.cores()),
                QosClass::Spot => self.qos.credit(QosClass::Spot, user, alloc.cores()),
            }
        }
        if self.cfg.event_driven {
            let at = self.clock.max(self.busy_until);
            self.request_trigger(at);
        }
    }

    /// Cancel a job (user `scancel`). Pending jobs leave the queue; running
    /// jobs release their allocation immediately (no epilog modeling for
    /// voluntary cancels); requeued jobs die before re-entering the queue.
    /// Returns false when the job is unknown or already terminal.
    pub fn cancel(&mut self, id: JobId) -> bool {
        let ok = self.cancel_inner(id);
        if ok {
            self.version += 1;
        }
        ok
    }

    fn cancel_inner(&mut self, id: JobId) -> bool {
        let Some(job) = self.jobs.get_mut(&id) else {
            return false;
        };
        match job.state {
            JobState::Pending => {
                job.transition(JobState::Cancelled, self.clock);
                self.log.push(self.clock, id, LogKind::Ended);
                self.mark_terminal(id, self.clock);
                self.remove_from_pending(id);
                self.earliest_start.remove(&id);
                self.clear_reservation(id);
                true
            }
            JobState::Running => {
                job.transition(JobState::Cancelled, self.clock);
                let (user, qos) = (job.spec.user, job.spec.qos);
                self.log.push(self.clock, id, LogKind::Ended);
                self.mark_terminal(id, self.clock);
                if let Some(alloc) = self.cluster.release(id) {
                    match qos {
                        QosClass::Normal => self.users.credit(user, alloc.cores()),
                        QosClass::Spot => self.qos.credit(QosClass::Spot, user, alloc.cores()),
                    }
                }
                if self.cfg.event_driven {
                    let at = self.clock.max(self.busy_until);
                    self.request_trigger(at);
                }
                true
            }
            JobState::Requeued => {
                job.transition(JobState::Cancelled, self.clock);
                self.log.push(self.clock, id, LogKind::Ended);
                self.mark_terminal(id, self.clock);
                true
            }
            JobState::Suspended => {
                job.transition(JobState::Cancelled, self.clock);
                let (user, qos) = (job.spec.user, job.spec.qos);
                self.log.push(self.clock, id, LogKind::Ended);
                self.mark_terminal(id, self.clock);
                self.suspended.remove(&id);
                if let Some(alloc) = self.cluster.release(id) {
                    match qos {
                        QosClass::Normal => self.users.credit(user, alloc.cores()),
                        QosClass::Spot => self.qos.credit(QosClass::Spot, user, alloc.cores()),
                    }
                }
                // Bugfix: like the Running branch, cancelling a suspended
                // job frees its allocation — without a trigger the freed
                // cores sat idle until the next periodic cycle under
                // event_driven (regression test below).
                if self.cfg.event_driven {
                    let at = self.clock.max(self.busy_until);
                    self.request_trigger(at);
                }
                true
            }
            JobState::Completed | JobState::Cancelled => false,
        }
    }

    fn on_cron_tick(&mut self) {
        if let PreemptApproach::CronAgent { mode, cfg } = self.cfg.approach.clone() {
            self.stats.cron_passes += 1;
            crate::preempt::cron::cron_pass(self, mode, &cfg);
            self.events
                .push(self.clock + self.cfg.costs.cron_interval, Event::CronTick);
        }
    }

    /// Whole-scheduler invariant check (used by property tests):
    /// cluster-node accounting, QoS/user usage vs actual allocations, and
    /// state/allocation consistency.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.cluster.check_invariants()?;
        let mut normal_by_user: BTreeMap<crate::job::UserId, u32> = BTreeMap::new();
        let mut spot_total = 0u32;
        let mut spot_by_user: BTreeMap<crate::job::UserId, u32> = BTreeMap::new();
        for id in self.cluster.allocated_jobs() {
            let job = self
                .jobs
                .get(&id)
                .ok_or_else(|| format!("allocation for unknown job {id}"))?;
            if !job.state.holds_resources() {
                return Err(format!("{id} holds an allocation in state {:?}", job.state));
            }
            let cores = self.cluster.allocation_of(id).expect("listed").cores();
            match job.spec.qos {
                QosClass::Normal => *normal_by_user.entry(job.spec.user).or_default() += cores,
                QosClass::Spot => {
                    spot_total += cores;
                    *spot_by_user.entry(job.spec.user).or_default() += cores;
                }
            }
        }
        for (&user, &cores) in &normal_by_user {
            if self.users.usage(user) != cores {
                return Err(format!(
                    "user accounting mismatch for {user}: charged {} vs allocated {cores}",
                    self.users.usage(user)
                ));
            }
        }
        if self.qos.total_usage(QosClass::Spot) != spot_total {
            return Err(format!(
                "spot QoS accounting mismatch: charged {} vs allocated {spot_total}",
                self.qos.total_usage(QosClass::Spot)
            ));
        }
        for (&user, &cores) in &spot_by_user {
            if self.qos.usage(QosClass::Spot, user) != cores {
                return Err(format!("spot user accounting mismatch for {user}"));
            }
        }
        // Pending queues only contain pending jobs, each exactly once; the
        // job→partition index and per-queue Normal counters stay in sync.
        let mut seen = BTreeSet::new();
        for (&pid, q) in &self.queues {
            let mut normal = 0usize;
            for id in q.ids() {
                if !seen.insert(id) {
                    return Err(format!("{id} queued twice"));
                }
                let Some(job) = self.jobs.get(&id) else {
                    return Err(format!("{id} queued but not in the job table"));
                };
                if job.state != JobState::Pending {
                    return Err(format!("{id} in pending queue with state {:?}", job.state));
                }
                if job.spec.qos == QosClass::Normal {
                    normal += 1;
                }
                if self.job_partition.get(&id) != Some(&pid) {
                    return Err(format!("{id} queued in {pid:?} but indexed elsewhere"));
                }
            }
            if normal != q.normal_pending() {
                return Err(format!(
                    "{pid:?}: normal-pending counter {} vs {normal} queued",
                    q.normal_pending()
                ));
            }
        }
        if self.job_partition.len() != seen.len() {
            return Err(format!(
                "job→partition index has {} entries for {} queued jobs",
                self.job_partition.len(),
                seen.len()
            ));
        }
        // Reservation aggregate matches the table; reservations only ever
        // belong to pending jobs (the O(1) pass-loop counter relies on it).
        let reserved_sum: u32 = self.reservations.values().copied().sum();
        if reserved_sum != self.reserved_pending_cores {
            return Err(format!(
                "reservation counter {} vs table sum {reserved_sum}",
                self.reserved_pending_cores
            ));
        }
        for &id in self.reservations.keys() {
            let st = self.jobs.get(&id).map(|j| j.state);
            if st != Some(JobState::Pending) {
                return Err(format!("reservation held by {id} in state {st:?}"));
            }
        }
        // The suspended set mirrors job states exactly.
        for &id in &self.suspended {
            let st = self.jobs.get(&id).map(|j| j.state);
            if st != Some(JobState::Suspended) {
                return Err(format!("{id} in suspended set with state {st:?}"));
            }
        }
        let actually_suspended =
            self.jobs.values().filter(|j| j.state == JobState::Suspended).count();
        if actually_suspended != self.suspended.len() {
            return Err(format!(
                "suspended set has {} entries for {actually_suspended} suspended jobs",
                self.suspended.len()
            ));
        }
        Ok(())
    }

    // ---- internals used by the preempt engines -----------------------------

    pub(crate) fn qos_mut(&mut self) -> &mut QosTable {
        &mut self.qos
    }

    pub(crate) fn costs(&self) -> &crate::sim::SchedCosts {
        &self.cfg.costs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology;
    use crate::job::{JobType, UserId};
    use crate::sim::SchedCosts;

    fn baseline_sched() -> Scheduler {
        Scheduler::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), crate::cluster::PartitionLayout::Dual),
        )
    }

    #[test]
    fn baseline_triple_dispatches_fast() {
        let mut s = baseline_sched();
        let id = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
        assert!(s.run_until_dispatched(&[id], SimTime::from_secs(60)));
        let m = s.log().measure(&[id]).unwrap();
        // 19 node scripts at ~10ms + overheads: well under a second.
        assert!(m.total_secs < 1.0, "triple-mode took {}s", m.total_secs);
        assert_eq!(s.job(id).unwrap().state, JobState::Running);
    }

    #[test]
    fn baseline_array_costs_per_task() {
        let mut s = baseline_sched();
        let id = s.submit(JobSpec::interactive(UserId(1), JobType::Array, 608));
        assert!(s.run_until_dispatched(&[id], SimTime::from_secs(120)));
        let m = s.log().measure(&[id]).unwrap();
        let per_task = m.per_task(608);
        assert!(
            (0.005..0.05).contains(&per_task),
            "array per-task {per_task}s"
        );
    }

    #[test]
    fn individual_burst_fills_cluster() {
        let mut s = baseline_sched();
        let specs = (0..608)
            .map(|_| JobSpec::interactive(UserId(1), JobType::Individual, 1))
            .collect();
        let ids = s.submit_burst(specs);
        assert!(s.run_until_dispatched(&ids, SimTime::from_secs(300)));
        assert_eq!(s.cluster().idle_cores(), 0);
        let m = s.log().measure(&ids).unwrap();
        assert_eq!(m.jobs_dispatched, 608);
    }

    #[test]
    fn blocked_job_waits_for_resources() {
        let mut s = baseline_sched();
        let big = s.submit(
            JobSpec::interactive(UserId(1), JobType::Array, 608)
                .with_run_time(SimTime::from_secs(100)),
        );
        assert!(s.run_until_dispatched(&[big], SimTime::from_secs(60)));
        let second = s.submit(JobSpec::interactive(UserId(2), JobType::Array, 32));
        s.run_until(SimTime::from_secs(50));
        assert_eq!(s.job(second).unwrap().state, JobState::Pending);
        // After the first job ends, the second dispatches (event-driven).
        assert!(s.run_until_dispatched(&[second], SimTime::from_secs(400)));
        assert_eq!(s.job(big).unwrap().state, JobState::Completed);
    }

    #[test]
    fn user_limit_blocks_oversized() {
        let cfg = SchedulerConfig::baseline(
            SchedCosts::dedicated(),
            crate::cluster::PartitionLayout::Dual,
        )
        .with_user_limit(100);
        let mut s = Scheduler::new(topology::tx2500(), cfg);
        let id = s.submit(JobSpec::interactive(UserId(1), JobType::Array, 200));
        s.run_until(SimTime::from_secs(120));
        assert_eq!(s.job(id).unwrap().state, JobState::Pending, "over-limit job must wait");
        // A job within the limit passes.
        let ok = s.submit(JobSpec::interactive(UserId(1), JobType::Array, 100));
        assert!(s.run_until_dispatched(&[ok], SimTime::from_secs(240)));
    }

    #[test]
    fn large_individual_burst_drains_with_invariants() {
        // The scaling workload in miniature: the queue layer must keep a
        // multi-thousand-job individual burst consistent end to end.
        let mut s = baseline_sched();
        let specs = (0..2000)
            .map(|i| {
                JobSpec::interactive(UserId(1 + (i % 4) as u32), JobType::Individual, 1)
                    .with_run_time(SimTime::from_secs(1))
            })
            .collect();
        let ids = s.submit_burst(specs);
        assert!(s.run_until_dispatched(&ids, SimTime::from_secs(4 * 3600)));
        assert_eq!(s.stats().dispatches, 2000);
        s.check_invariants().unwrap();
    }

    #[test]
    fn cancelling_suspended_job_triggers_immediate_pass() {
        // Regression: cancelling a Suspended job freed its allocation but
        // never called request_trigger, so freed cores idled until the next
        // periodic cycle. Periodic cycles are pushed out to make the
        // event-driven trigger the only dispatch path.
        let mut costs = SchedCosts::dedicated();
        costs.main_cycle_period = SimTime::from_secs(1_000_000);
        costs.backfill_cycle_period = SimTime::from_secs(1_000_000);
        let cfg = SchedulerConfig::baseline(costs, crate::cluster::PartitionLayout::Dual)
            .with_approach(crate::preempt::PreemptApproach::AutoScheduler {
                mode: crate::preempt::PreemptMode::Suspend,
            });
        let mut s = Scheduler::new(topology::tx2500(), cfg);
        let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 608));
        assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(60)));
        // The preemptor suspends the spot job but cannot use its memory,
        // and defers itself far into the future (cycle-based retry).
        let preemptor = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
        s.run_for(SimTime::from_secs(60));
        assert_eq!(s.job(spot).unwrap().state, JobState::Suspended);
        // A second interactive job stays eligible but blocked (a suspended
        // victim is no longer preemptable).
        let second = s.submit(JobSpec::interactive(UserId(2), JobType::Array, 32));
        s.run_for(SimTime::from_secs(30));
        assert_eq!(s.job(second).unwrap().state, JobState::Pending);
        // Cancelling the suspended job frees 608 cores; the event-driven
        // trigger must dispatch the blocked job promptly.
        assert!(s.cancel(spot));
        assert!(
            s.run_until_dispatched(&[second], SimTime::from_secs(30)),
            "freed cores after a suspended-job cancel must trigger a pass"
        );
        assert_eq!(s.job(preemptor).unwrap().state, JobState::Pending);
        s.check_invariants().unwrap();
    }

    #[test]
    fn retire_terminal_removes_old_jobs_and_moves_signature() {
        let mut s = baseline_sched();
        let id = s.submit(
            JobSpec::interactive(UserId(1), JobType::Individual, 1)
                .with_run_time(SimTime::from_secs(1)),
        );
        assert!(s.run_until_dispatched(&[id], SimTime::from_secs(60)));
        s.run_for(SimTime::from_secs(120));
        assert_eq!(s.job(id).unwrap().state, JobState::Completed);
        let sig = s.jobs_signature();
        assert!(
            s.retire_terminal(SimTime::from_secs(100_000)).is_empty(),
            "grace not elapsed"
        );
        let retired = s.retire_terminal(SimTime::from_secs(10));
        assert_eq!(retired.len(), 1);
        assert_eq!(retired[0].id, id);
        assert!(s.job(id).is_none(), "retired job leaves the table");
        assert_ne!(s.jobs_signature(), sig, "retirement must move the signature");
        assert_eq!(s.retired_total(), 1);
        assert!(!s.cancel(id), "retired job cannot be cancelled");
        s.check_invariants().unwrap();
    }

    #[test]
    fn pruned_retired_log_keeps_monotone_facts() {
        let mut s = baseline_sched();
        let id = s.submit(
            JobSpec::interactive(UserId(1), JobType::Individual, 1)
                .with_run_time(SimTime::from_secs(1)),
        );
        assert!(s.run_until_dispatched(&[id], SimTime::from_secs(60)));
        s.run_for(SimTime::from_secs(120));
        let retired = s.retire_terminal(SimTime::from_secs(10));
        assert_eq!(retired.len(), 1);
        let appended = s.log().appended_total();
        let ended = s.log().count(LogKind::Ended);
        let sig = s.jobs_signature();
        s.prune_retired_log(retired.iter().map(|j| j.id));
        // The pruned job answers nothing anymore…
        assert!(s.log().first(id, LogKind::Recognized).is_none());
        assert!(s.log().last(id, LogKind::DispatchDone).is_none());
        // …but the monotone facts (and so the signature) are unmoved.
        assert_eq!(s.log().appended_total(), appended);
        assert_eq!(s.log().count(LogKind::Ended), ended);
        assert_eq!(s.jobs_signature(), sig, "pruning must not move the signature");
        // Running on after a prune must stay sound.
        s.run_for(SimTime::from_secs(60));
        s.check_invariants().unwrap();
    }

    #[test]
    fn stale_job_end_after_retirement_is_ignored() {
        // Regression: a cancelled running job keeps its scheduled JobEnd in
        // the event queue. If the record is retired before that stale event
        // fires (run time > grace period), the handler must ignore it, not
        // panic — a panic here takes down a long-lived daemon.
        let mut s = baseline_sched();
        let id = s.submit(
            JobSpec::interactive(UserId(1), JobType::Individual, 1)
                .with_run_time(SimTime::from_secs(10_000)),
        );
        assert!(s.run_until_dispatched(&[id], SimTime::from_secs(60)));
        assert!(s.cancel(id)); // JobEnd at ~10_000s stays queued
        s.run_for(SimTime::from_secs(120));
        let retired = s.retire_terminal(SimTime::from_secs(10));
        assert_eq!(retired.len(), 1);
        // Run far past the stale JobEnd time: must not panic.
        s.run_for(SimTime::from_secs(20_000));
        s.check_invariants().unwrap();
        assert_eq!(s.stats().dispatches, 1);
    }

    #[test]
    fn spot_and_interactive_coexist_dual() {
        let mut s = baseline_sched();
        let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 320));
        assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(60)));
        let inter = s.submit(JobSpec::interactive(UserId(1), JobType::Array, 288));
        assert!(s.run_until_dispatched(&[inter], SimTime::from_secs(120)));
        assert_eq!(s.cluster().idle_cores(), 0);
        s.cluster().check_invariants().unwrap();
    }
}
