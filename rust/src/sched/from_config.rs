//! Build a cluster + scheduler configuration from a `slurm.conf`-style file
//! (see [`crate::util::config`]), so deployments are file-describable like
//! the real system the paper modifies.
//!
//! Recognized keys (case-insensitive, `Key=Value`, `#` comments):
//!
//! ```text
//! ClusterName=tx-2500          # label only
//! Nodes=19                     # node count
//! CoresPerNode=32
//! CostPreset=dedicated         # dedicated | production
//! PartitionLayout=dual         # single | dual
//! PreemptApproach=cron         # none | auto | manual | cron
//! PreemptMode=REQUEUE          # REQUEUE | CANCEL | SUSPEND | GANG
//! ReserveNodes=5               # cron agent reserve
//! UserCoreLimit=160
//! CronIntervalSecs=60
//! RequeueHoldSecs=60
//! PhaseSeed=1
//! SchedulerParameters=preempt_youngest_first,bf_interval=30
//! ```

use crate::cluster::{Cluster, PartitionLayout};
use crate::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use crate::sched::SchedulerConfig;
use crate::sim::{SchedCosts, SimTime};
use crate::bail;
use crate::ensure;
use crate::util::config::ConfigFile;
use crate::util::error::{Context, Result};

/// A fully-described deployment: cluster + scheduler config.
pub struct Deployment {
    /// Cluster label from `ClusterName`.
    pub name: String,
    /// The hardware.
    pub cluster: Cluster,
    /// The scheduler configuration.
    pub config: SchedulerConfig,
}

/// Parse a deployment from config text.
pub fn deployment_from_text(text: &str) -> Result<Deployment> {
    let cfg = ConfigFile::parse(text).context("parsing config")?;
    deployment_from_config(&cfg)
}

/// Parse a deployment from a config file on disk.
pub fn deployment_from_file(path: &std::path::Path) -> Result<Deployment> {
    let cfg = ConfigFile::load(path)?;
    deployment_from_config(&cfg)
}

/// Build from a parsed [`ConfigFile`].
pub fn deployment_from_config(cfg: &ConfigFile) -> Result<Deployment> {
    let name = cfg.get("ClusterName").unwrap_or("spotcloud").to_string();
    let nodes: u32 = cfg.get_parsed_or("Nodes", 19)?;
    let cores: u32 = cfg.get_parsed_or("CoresPerNode", 32)?;
    ensure!(nodes > 0 && cores > 0, "Nodes and CoresPerNode must be positive");
    let cluster = Cluster::homogeneous(nodes, cores);

    let mut costs = match cfg.get("CostPreset").unwrap_or("dedicated") {
        "dedicated" => SchedCosts::dedicated(),
        "production" => SchedCosts::production(),
        other => bail!("unknown CostPreset {other:?} (dedicated | production)"),
    };
    costs.cron_interval = SimTime::from_secs(cfg.get_parsed_or("CronIntervalSecs", 60u64)?);
    // Honor Slurm-style SchedulerParameters where we model them.
    let (_flags, kvs) = cfg.option_list("SchedulerParameters");
    if let Some(bf) = kvs.get("bf_interval") {
        costs.backfill_cycle_period =
            SimTime::from_secs(bf.parse::<u64>().context("bf_interval")?);
    }
    if let Some(si) = kvs.get("sched_interval") {
        costs.main_cycle_period = SimTime::from_secs(si.parse::<u64>().context("sched_interval")?);
    }
    if let Some(bt) = kvs.get("bf_max_job_test") {
        costs.bf_max_job_test = bt.parse::<usize>().context("bf_max_job_test")?;
    }

    let layout = match cfg.get("PartitionLayout").unwrap_or("dual") {
        "single" => PartitionLayout::Single,
        "dual" => PartitionLayout::Dual,
        other => bail!("unknown PartitionLayout {other:?} (single | dual)"),
    };

    let mode = match cfg.get("PreemptMode").unwrap_or("REQUEUE").to_ascii_uppercase().as_str() {
        "REQUEUE" => PreemptMode::Requeue,
        "CANCEL" => PreemptMode::Cancel,
        "SUSPEND" => PreemptMode::Suspend,
        "GANG" => PreemptMode::Gang,
        other => bail!("unknown PreemptMode {other:?}"),
    };
    let reserve_nodes: u32 = cfg.get_parsed_or("ReserveNodes", 5)?;
    let approach = match cfg.get("PreemptApproach").unwrap_or("none") {
        "none" => PreemptApproach::None,
        "auto" => PreemptApproach::AutoScheduler { mode },
        "manual" => PreemptApproach::Manual { mode },
        "cron" => PreemptApproach::CronAgent {
            mode,
            cfg: CronAgentConfig { reserve_nodes },
        },
        other => bail!("unknown PreemptApproach {other:?} (none | auto | manual | cron)"),
    };

    let mut sched_cfg = SchedulerConfig::baseline(costs, layout)
        .with_approach(approach)
        .with_user_limit(cfg.get_parsed_or("UserCoreLimit", 4096)?)
        .with_phase_seed(cfg.get_parsed_or("PhaseSeed", 0x5107_c10du64)?)
        .with_lua_plugin(cfg.get_bool_or("LuaPlugin", false)?);
    sched_cfg.requeue_hold = SimTime::from_secs(cfg.get_parsed_or("RequeueHoldSecs", 60u64)?);
    sched_cfg.event_driven = cfg.get_bool_or("EventDriven", true)?;

    Ok(Deployment {
        name,
        cluster,
        config: sched_cfg,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# the paper's dev cluster with the cron-agent approach
ClusterName=tx-2500
Nodes=19
CoresPerNode=32
CostPreset=dedicated
PartitionLayout=dual
PreemptApproach=cron
PreemptMode=REQUEUE
ReserveNodes=5
UserCoreLimit=160
CronIntervalSecs=60
SchedulerParameters=preempt_youngest_first,bf_interval=45,sched_interval=20,bf_max_job_test=250
"#;

    #[test]
    fn parses_the_sample() {
        let d = deployment_from_text(SAMPLE).unwrap();
        assert_eq!(d.name, "tx-2500");
        assert_eq!(d.cluster.total_cores(), 608);
        assert_eq!(d.config.user_core_limit, 160);
        assert!(matches!(
            d.config.approach,
            PreemptApproach::CronAgent {
                mode: PreemptMode::Requeue,
                cfg: CronAgentConfig { reserve_nodes: 5 }
            }
        ));
        assert_eq!(d.config.costs.backfill_cycle_period, SimTime::from_secs(45));
        assert_eq!(d.config.costs.main_cycle_period, SimTime::from_secs(20));
        assert_eq!(d.config.costs.bf_max_job_test, 250);
    }

    #[test]
    fn defaults_give_a_baseline_tx2500() {
        let d = deployment_from_text("").unwrap();
        assert_eq!(d.cluster.total_cores(), 608);
        assert!(matches!(d.config.approach, PreemptApproach::None));
    }

    #[test]
    fn deployment_actually_schedules() {
        use crate::job::{JobSpec, JobType, UserId};
        let d = deployment_from_text(SAMPLE).unwrap();
        let mut s = crate::sched::Scheduler::new(d.cluster, d.config);
        let spot = s.submit(JobSpec::spot(UserId(9), JobType::TripleMode, 448));
        assert!(s.run_until_dispatched(&[spot], SimTime::from_secs(300)));
        let j = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 160));
        assert!(s.run_until_dispatched(&[j], SimTime::from_secs(60)));
        assert!(s.log().measure(&[j]).unwrap().total_secs < 1.0);
    }

    #[test]
    fn bad_values_rejected() {
        assert!(deployment_from_text("Nodes=0").is_err());
        assert!(deployment_from_text("CostPreset=warp").is_err());
        assert!(deployment_from_text("PreemptApproach=psychic").is_err());
        assert!(deployment_from_text("PreemptMode=HARDER").is_err());
        assert!(deployment_from_text("PartitionLayout=triple").is_err());
    }
}
