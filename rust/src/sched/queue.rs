//! Incrementally maintained per-partition pending queues.
//!
//! The old queue layer kept a flat `Vec<JobId>` per partition plus a cached
//! priority order that was **cleared globally** whenever fairshare moved —
//! i.e. on every dispatch, job end, preemption, and cancel. A burst of N
//! individual submissions therefore re-scored and re-sorted the full O(N)
//! queue once per pass, and every dispatch removed from the queue by linear
//! scan: quadratic on exactly the workload the paper cares about.
//!
//! This module replaces it with a structure whose maintenance cost is
//! O(log n) per queue mutation and O(log u) per job *visited* by a pass
//! (u = users with pending jobs), built on two observations about the
//! multifactor score:
//!
//! 1. **Age is a common-rate term.** Every pending job's age factor grows at
//!    the same rate, so the pairwise order of two jobs is invariant over
//!    time. Each job gets a *static key*: its score at age 0 minus the age
//!    slope times its queue time — any two static keys compare exactly like
//!    the live scores do. (The 100 h age-factor cap is deliberately not
//!    applied to the ordering key: under the cap two >100 h-old jobs stop
//!    aging relative to *fresher* jobs, which the old per-pass rescore
//!    honored, but queues that old are outside every modeled workload and
//!    the uncapped key keeps the order strictly time-invariant.)
//! 2. **Fairshare is a per-(qos, user) offset.** The fairshare factor is
//!    identical for all pending jobs of one user in one QoS class, so it
//!    never reorders jobs *within* a user — only *between* users. Jobs are
//!    therefore bucketed per (qos, user) and ordered inside the bucket by
//!    static key alone; a scheduling pass merges the buckets through a heap,
//!    applying each bucket's current fairshare offset to its head. A
//!    fairshare change costs nothing at mutation time and O(1) at the next
//!    pass — no per-job re-scoring, ever.
//!
//! Both observations hold for any scorer that is *affine* in the age and
//! fairshare factors, which covers the native dot-product scorer and the
//! XLA matvec kernel (the scheduler probes the two slopes once at
//! construction; see [`crate::sched::Scheduler`]).

use crate::job::{JobId, QosClass, UserId};
use crate::util::fxhash::FxHashMap;
use std::collections::{BTreeSet, BinaryHeap};
use std::cmp::Reverse;
use std::ops::Bound;

/// Total-ordered encoding of an `f64` priority score, **inverted** so that
/// ascending `OrderKey` order visits the highest score first (BTreeSet
/// iteration order == scheduling order). Ties between equal scores are
/// broken by ascending [`JobId`] wherever the key is paired with one,
/// matching the old sort's tie-break.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct OrderKey(u64);

impl OrderKey {
    /// Encode a score. Uses the standard monotone f64→u64 bit trick (flip
    /// all bits for negatives, set the sign bit for positives), then
    /// complements so *larger scores become smaller keys*.
    pub fn of_score(score: f64) -> Self {
        let bits = score.to_bits();
        let monotone = if bits & (1u64 << 63) != 0 {
            !bits
        } else {
            bits | (1u64 << 63)
        };
        OrderKey(!monotone)
    }

    /// Decode back to the score (exact inverse of [`OrderKey::of_score`]).
    pub fn score(self) -> f64 {
        let monotone = !self.0;
        let bits = if monotone & (1u64 << 63) != 0 {
            monotone ^ (1u64 << 63)
        } else {
            !monotone
        };
        f64::from_bits(bits)
    }
}

/// One user's pending jobs in one QoS class, ordered by static key.
#[derive(Debug, Default)]
struct UserBucket {
    jobs: BTreeSet<(OrderKey, JobId)>,
}

/// A partition's pending queue: per-(qos, user) buckets plus an O(1) job
/// index for removal.
#[derive(Debug, Default)]
pub struct PendingQueue {
    buckets: FxHashMap<(QosClass, UserId), UserBucket>,
    /// job → (qos, user, static key): makes removal O(log) with no scan.
    index: FxHashMap<JobId, (QosClass, UserId, OrderKey)>,
    /// Pending Normal-QoS jobs (the suspended-resume gate reads this).
    normal_pending: usize,
}

impl PendingQueue {
    /// Queue a job under its static priority key.
    pub fn insert(&mut self, id: JobId, qos: QosClass, user: UserId, key: OrderKey) {
        let prev = self.index.insert(id, (qos, user, key));
        debug_assert!(prev.is_none(), "{id} queued twice");
        self.buckets
            .entry((qos, user))
            .or_default()
            .jobs
            .insert((key, id));
        if qos == QosClass::Normal {
            self.normal_pending += 1;
        }
    }

    /// Remove a job; returns true when it was queued here.
    pub fn remove(&mut self, id: JobId) -> bool {
        let Some((qos, user, key)) = self.index.remove(&id) else {
            return false;
        };
        let bucket = self.buckets.get_mut(&(qos, user)).expect("indexed bucket");
        let removed = bucket.jobs.remove(&(key, id));
        debug_assert!(removed, "{id} indexed but not in its bucket");
        if bucket.jobs.is_empty() {
            self.buckets.remove(&(qos, user));
        }
        if qos == QosClass::Normal {
            self.normal_pending -= 1;
        }
        true
    }

    /// Queued job count.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// True when no jobs are queued.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Number of pending Normal-QoS jobs.
    pub fn normal_pending(&self) -> usize {
        self.normal_pending
    }

    /// Whether a job is queued here.
    pub fn contains(&self, id: JobId) -> bool {
        self.index.contains_key(&id)
    }

    /// Number of live (qos, user) buckets — i.e. distinct users with jobs
    /// *currently* pending here. Empty buckets are retired on removal, so
    /// this is the k of the pass-order k-way merge, not a historical count.
    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    /// All queued job ids (arbitrary order; invariant checks).
    pub fn ids(&self) -> impl Iterator<Item = JobId> + '_ {
        self.index.keys().copied()
    }

    /// The non-empty buckets and their best (key, id) head entries.
    fn bucket_heads(&self) -> impl Iterator<Item = ((QosClass, UserId), (OrderKey, JobId))> + '_ {
        self.buckets.iter().map(|(&bu, b)| {
            let head = *b.jobs.iter().next().expect("buckets are never empty");
            (bu, head)
        })
    }

    /// The entry strictly after `after` within one user's bucket.
    fn successor(
        &self,
        qos: QosClass,
        user: UserId,
        after: (OrderKey, JobId),
    ) -> Option<(OrderKey, JobId)> {
        self.buckets
            .get(&(qos, user))?
            .jobs
            .range((Bound::Excluded(after), Bound::Unbounded))
            .next()
            .copied()
    }
}

/// A heap entry of the pass-order merge: effective key (static + frozen
/// fairshare offset), then job id (global tie-break), then the bucket slot
/// and static key needed to advance within the bucket.
type PassEntry = Reverse<(OrderKey, JobId, u32, OrderKey)>;

/// The priority order of one partition for the duration of one scheduling
/// pass: a lazy k-way merge over the user buckets with each bucket's
/// fairshare offset *frozen at pass start* (the old cached-order semantics:
/// fairshare changes made by the pass itself only affect the next pass).
///
/// Pulling the next job is O(log u); a Main pass that stops at the first
/// blocked job therefore does O(u + visited · log u) work instead of
/// re-scoring and cloning the whole queue.
///
/// The structure is reusable: [`PassOrder::rebuild`] refills a drained
/// order in place, retaining both allocations across passes and seeding the
/// heap by O(u) bulk heapify instead of u pushes — at 10⁶ pending users the
/// per-pass setup drops from O(u log u) comparisons plus two fresh
/// allocations to a linear sweep over warm memory.
#[derive(Debug, Default)]
pub struct PassOrder {
    heap: BinaryHeap<PassEntry>,
    /// Per-slot bucket identity (for successor queries).
    slots: Vec<(QosClass, UserId, f64)>,
}

impl PassOrder {
    /// Build the frozen order. `offset_of` maps (qos, user) to the bucket's
    /// fairshare score offset at pass start.
    pub fn build(queue: &PendingQueue, offset_of: impl FnMut(QosClass, UserId) -> f64) -> Self {
        let mut order = PassOrder::default();
        order.rebuild(queue, offset_of);
        order
    }

    /// Refill this order for a new pass, reusing the heap and slot-table
    /// allocations from previous passes. Any entries left from an
    /// early-terminated prior pass are discarded.
    pub fn rebuild(
        &mut self,
        queue: &PendingQueue,
        mut offset_of: impl FnMut(QosClass, UserId) -> f64,
    ) {
        self.slots.clear();
        self.slots.reserve(queue.buckets.len());
        // Borrow the heap's buffer as a plain Vec: filling it unordered and
        // converting back heapifies in O(u) rather than pushing u times.
        let mut entries = std::mem::take(&mut self.heap).into_vec();
        entries.clear();
        entries.reserve(queue.buckets.len());
        for ((qos, user), (key, id)) in queue.bucket_heads() {
            let off = offset_of(qos, user);
            let slot = self.slots.len() as u32;
            self.slots.push((qos, user, off));
            entries.push(Reverse((
                OrderKey::of_score(key.score() + off),
                id,
                slot,
                key,
            )));
        }
        self.heap = BinaryHeap::from(entries);
    }

    /// Pop the next job in priority order. The successor inside the popped
    /// job's bucket is queued immediately, so the caller is free to remove
    /// the returned job from `queue` (dispatch) before the next call — the
    /// pass order stays frozen either way.
    pub fn next(&mut self, queue: &PendingQueue) -> Option<JobId> {
        let Reverse((_eff, id, slot, key)) = self.heap.pop()?;
        let (qos, user, off) = self.slots[slot as usize];
        if let Some((nk, nid)) = queue.successor(qos, user, (key, id)) {
            self.heap.push(Reverse((
                OrderKey::of_score(nk.score() + off),
                nid,
                slot,
                nk,
            )));
        }
        Some(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jid(n: u64) -> JobId {
        JobId(n)
    }

    #[test]
    fn order_key_roundtrips_and_orders() {
        for s in [-1.5e9, -1.0, -0.0, 0.0, 1.0, 42.25, 1.5e9] {
            let k = OrderKey::of_score(s);
            assert_eq!(k.score(), s, "roundtrip of {s}");
        }
        // Higher score → smaller key (sorts first).
        assert!(OrderKey::of_score(10.0) < OrderKey::of_score(1.0));
        assert!(OrderKey::of_score(1.0) < OrderKey::of_score(-1.0));
        assert!(OrderKey::of_score(-1.0) < OrderKey::of_score(-10.0));
    }

    #[test]
    fn insert_remove_and_counts() {
        let mut q = PendingQueue::default();
        q.insert(jid(1), QosClass::Normal, UserId(1), OrderKey::of_score(5.0));
        q.insert(jid(2), QosClass::Spot, UserId(9), OrderKey::of_score(7.0));
        assert_eq!(q.len(), 2);
        assert_eq!(q.normal_pending(), 1);
        assert!(q.contains(jid(1)));
        assert!(q.remove(jid(1)));
        assert!(!q.remove(jid(1)), "double remove is a no-op");
        assert_eq!(q.normal_pending(), 0);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn pass_order_merges_buckets_by_effective_score() {
        let mut q = PendingQueue::default();
        // User 1: two jobs at 10 and 8. User 2: one job at 9.
        q.insert(jid(1), QosClass::Normal, UserId(1), OrderKey::of_score(10.0));
        q.insert(jid(2), QosClass::Normal, UserId(1), OrderKey::of_score(8.0));
        q.insert(jid(3), QosClass::Normal, UserId(2), OrderKey::of_score(9.0));
        let mut order = PassOrder::build(&q, |_, _| 0.0);
        let got: Vec<JobId> = std::iter::from_fn(|| order.next(&q)).collect();
        assert_eq!(got, vec![jid(1), jid(3), jid(2)]);

        // A fairshare offset against user 1 reorders across users but
        // never within a user.
        let mut order = PassOrder::build(&q, |_, u| if u == UserId(1) { -3.0 } else { 0.0 });
        let got: Vec<JobId> = std::iter::from_fn(|| order.next(&q)).collect();
        assert_eq!(got, vec![jid(3), jid(1), jid(2)]);
    }

    #[test]
    fn pass_order_ties_break_by_job_id() {
        let mut q = PendingQueue::default();
        q.insert(jid(7), QosClass::Normal, UserId(1), OrderKey::of_score(1.0));
        q.insert(jid(3), QosClass::Normal, UserId(2), OrderKey::of_score(1.0));
        q.insert(jid(5), QosClass::Normal, UserId(3), OrderKey::of_score(1.0));
        let mut order = PassOrder::build(&q, |_, _| 0.0);
        let got: Vec<JobId> = std::iter::from_fn(|| order.next(&q)).collect();
        assert_eq!(got, vec![jid(3), jid(5), jid(7)]);
    }

    #[test]
    fn pass_order_rebuild_reuses_and_matches_fresh_build() {
        let mut q = PendingQueue::default();
        for i in 1..=64 {
            q.insert(
                jid(i),
                QosClass::Normal,
                UserId(i as u32 % 7),
                OrderKey::of_score(100.0 - i as f64),
            );
        }
        let mut reused = PassOrder::default();
        // Drain part of a pass, then rebuild: the refilled order must be
        // identical to a from-scratch build, including after queue churn.
        reused.rebuild(&q, |_, _| 0.0);
        for _ in 0..10 {
            reused.next(&q);
        }
        q.remove(jid(64));
        reused.rebuild(&q, |_, u| -(u.0 as f64));
        let mut fresh = PassOrder::build(&q, |_, u| -(u.0 as f64));
        let a: Vec<JobId> = std::iter::from_fn(|| reused.next(&q)).collect();
        let b: Vec<JobId> = std::iter::from_fn(|| fresh.next(&q)).collect();
        assert_eq!(a, b);
        assert_eq!(a.len(), q.len());
    }

    #[test]
    fn bucket_count_tracks_live_users() {
        let mut q = PendingQueue::default();
        q.insert(jid(1), QosClass::Normal, UserId(1), OrderKey::of_score(1.0));
        q.insert(jid(2), QosClass::Normal, UserId(1), OrderKey::of_score(2.0));
        q.insert(jid(3), QosClass::Spot, UserId(1), OrderKey::of_score(3.0));
        assert_eq!(q.bucket_count(), 2, "same user, two qos classes");
        q.remove(jid(1));
        assert_eq!(q.bucket_count(), 2, "bucket still holds jid 2");
        q.remove(jid(2));
        assert_eq!(q.bucket_count(), 1, "emptied bucket is retired");
    }

    #[test]
    fn pass_order_survives_mid_iteration_removal() {
        let mut q = PendingQueue::default();
        for i in 1..=4 {
            q.insert(jid(i), QosClass::Normal, UserId(1), OrderKey::of_score(10.0 - i as f64));
        }
        let mut order = PassOrder::build(&q, |_, _| 0.0);
        let first = order.next(&q).unwrap();
        assert_eq!(first, jid(1));
        // Dispatch removes the visited job; iteration continues unharmed.
        q.remove(first);
        let rest: Vec<JobId> = std::iter::from_fn(|| order.next(&q)).collect();
        assert_eq!(rest, vec![jid(2), jid(3), jid(4)]);
    }
}
