//! Multifactor job priority (Slurm's priority/multifactor plugin, reduced
//! to the factors that matter for the paper's experiments) and the
//! [`PriorityScorer`] abstraction that lets the scheduler's batched scoring
//! run either natively or on the AOT-compiled XLA kernel
//! (`runtime::accel::SchedAccel`).

use crate::job::Job;
use crate::sim::SimTime;

/// Number of priority factors. Must match `python/compile/model.py`'s
/// `N_FACTORS` — the AOT kernel is compiled for exactly this width.
pub const N_FACTORS: usize = 8;

/// Factor vector for one pending job, normalized to comparable magnitudes.
///
/// Layout (index → meaning) — keep in sync with `python/compile/model.py`:
/// 0: QoS priority (normalized by 1000)
/// 1: queue age in hours (caps at ~100h)
/// 2: job size in cores / 1024 (Slurm's smallest-first would negate this;
///    MIT SuperCloud favors neither, weight is small)
/// 3: requeue count (preempted jobs age faster so they eventually run)
/// 4: partition priority
/// 5: fairshare — the user's current share of allocated cores in [0,1]
///    (negative weight: heavy users sort later within a QoS class)
/// 6-7: reserved (zero) — padding for the XLA kernel's fixed width.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobFactors(pub [f32; N_FACTORS]);

impl JobFactors {
    /// Extract factors from a job record at virtual time `now`.
    pub fn of(
        job: &Job,
        qos_priority: u32,
        partition_priority: u32,
        user_usage_share: f32,
        now: SimTime,
    ) -> Self {
        let age_hours = now.saturating_sub(job.queue_time).as_secs_f64() / 3600.0;
        let mut f = [0.0f32; N_FACTORS];
        f[0] = qos_priority as f32 / 1000.0;
        f[1] = (age_hours as f32).min(100.0);
        f[2] = job.spec.cores() as f32 / 1024.0;
        f[3] = job.requeue_count as f32;
        f[4] = partition_priority as f32 / 1000.0;
        f[5] = user_usage_share.clamp(0.0, 1.0);
        JobFactors(f)
    }
}

/// The weight vector. Must match `python/compile/model.py`'s `WEIGHTS`.
pub const WEIGHTS: [f32; N_FACTORS] = [
    1000.0, // qos dominates: Normal always outranks Spot
    1.0,    // age
    0.1,    // size
    5.0,    // requeue bonus
    10.0,   // partition
    -50.0,  // fairshare (heavier current usage sorts later)
    0.0, 0.0,
];

/// Batched priority scoring. The scheduler calls this once per cycle for the
/// whole pending queue; implementations are the native fallback below and
/// the XLA-compiled kernel in `runtime::accel`.
pub trait PriorityScorer {
    /// Score each factor row; higher = schedule earlier.
    fn scores(&self, factors: &[JobFactors]) -> Vec<f32>;

    /// Human-readable backend name (for reports).
    fn name(&self) -> &'static str;
}

/// Pure-Rust reference implementation: `score = dot(factors, WEIGHTS)`.
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeScorer;

impl PriorityScorer for NativeScorer {
    fn scores(&self, factors: &[JobFactors]) -> Vec<f32> {
        factors
            .iter()
            .map(|f| f.0.iter().zip(WEIGHTS.iter()).map(|(x, w)| x * w).sum())
            .collect()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobId, JobSpec, JobType, UserId};

    fn job(tasks: u32, queue_at: u64) -> Job {
        Job::new(
            JobId(1),
            JobSpec::interactive(UserId(1), JobType::Array, tasks),
            SimTime::from_secs(queue_at),
        )
    }

    #[test]
    fn qos_dominates_age() {
        let now = SimTime::from_secs(100 * 3600);
        let old_spot = JobFactors::of(&job(64, 0), 10, 0, 0.0, now);
        let new_normal = JobFactors::of(&job(64, 100 * 3600 - 1), 1000, 0, 0.0, now);
        let s = NativeScorer.scores(&[old_spot, new_normal]);
        assert!(
            s[1] > s[0],
            "fresh normal job must outrank a spot job aged 100h: {s:?}"
        );
    }

    #[test]
    fn age_breaks_ties_within_qos() {
        let now = SimTime::from_secs(7200);
        let older = JobFactors::of(&job(64, 0), 1000, 0, 0.0, now);
        let newer = JobFactors::of(&job(64, 3600), 1000, 0, 0.0, now);
        let s = NativeScorer.scores(&[older, newer]);
        assert!(s[0] > s[1]);
    }

    #[test]
    fn requeue_count_boosts() {
        let now = SimTime::from_secs(60);
        let mut j = job(64, 0);
        let fresh = JobFactors::of(&j, 10, 0, 0.0, now);
        j.requeue_count = 3;
        let requeued = JobFactors::of(&j, 10, 0, 0.0, now);
        let s = NativeScorer.scores(&[fresh, requeued]);
        assert!(s[1] > s[0]);
    }

    #[test]
    fn factor_extraction_caps_age() {
        let j = job(64, 0);
        let f = JobFactors::of(&j, 1000, 0, 0.0, SimTime::from_secs(1000 * 3600));
        assert_eq!(f.0[1], 100.0);
    }

    #[test]
    fn fairshare_deprioritizes_heavy_users() {
        let now = SimTime::from_secs(60);
        let light = JobFactors::of(&job(64, 0), 1000, 0, 0.0, now);
        let heavy = JobFactors::of(&job(64, 0), 1000, 0, 0.8, now);
        let s = NativeScorer.scores(&[light, heavy]);
        assert!(s[0] > s[1], "heavy user must sort later: {s:?}");
    }

    #[test]
    fn fairshare_never_overrides_qos() {
        // Even a user hogging the whole cluster outranks any spot job.
        let now = SimTime::from_secs(60);
        let hog_normal = JobFactors::of(&job(64, 0), 1000, 0, 1.0, now);
        let idle_spot = JobFactors::of(&job(64, 0), 10, 0, 0.0, now);
        let s = NativeScorer.scores(&[hog_normal, idle_spot]);
        assert!(s[0] > s[1]);
    }

    #[test]
    fn empty_batch() {
        assert!(NativeScorer.scores(&[]).is_empty());
    }
}
