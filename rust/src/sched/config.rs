//! Scheduler configuration.

use crate::cluster::PartitionLayout;
use crate::preempt::PreemptApproach;
use crate::sched::priority::{NativeScorer, PriorityScorer};
use crate::sim::{SchedCosts, SimTime};
use std::sync::Arc;

/// Configuration for a [`super::Scheduler`].
#[derive(Clone)]
pub struct SchedulerConfig {
    /// Calibrated latency model.
    pub costs: SchedCosts,
    /// Single vs dual partition configuration (paper Table I).
    pub layout: PartitionLayout,
    /// Preemption machinery.
    pub approach: PreemptApproach,
    /// Trigger a scheduling pass when resources free up (node epilog done,
    /// job ended). Slurm does this on both presets; the *auto-preemption*
    /// slowness comes from the preemptor job's deferral, not from missing
    /// triggers.
    pub event_driven: bool,
    /// Hold time before a requeued spot job becomes eligible again.
    pub requeue_hold: SimTime,
    /// Per-user interactive core limit (paper: 4096 on the production
    /// partition).
    pub user_core_limit: u32,
    /// Seed for scheduler-cycle phase jitter (run-to-run variance of which
    /// cycle picks a job up — the source of the paper's Fig 2g outliers).
    pub phase_seed: u64,
    /// Run the Lua job-submit plugin hook at job arrival (the paper's
    /// negative result; observational only).
    pub lua_plugin: bool,
    /// Batched priority scoring backend: native Rust or the AOT-compiled
    /// XLA kernel (`runtime::accel::SchedAccel`).
    pub scorer: Arc<dyn PriorityScorer + Send + Sync>,
}

impl std::fmt::Debug for SchedulerConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SchedulerConfig")
            .field("layout", &self.layout)
            .field("approach", &self.approach.label())
            .field("event_driven", &self.event_driven)
            .field("requeue_hold", &self.requeue_hold)
            .field("user_core_limit", &self.user_core_limit)
            .field("phase_seed", &self.phase_seed)
            .field("lua_plugin", &self.lua_plugin)
            .field("scorer", &self.scorer.name())
            .finish()
    }
}

impl SchedulerConfig {
    /// Baseline configuration (no preemption) with the given cost preset and
    /// partition layout.
    pub fn baseline(costs: SchedCosts, layout: PartitionLayout) -> Self {
        Self {
            costs,
            layout,
            approach: PreemptApproach::None,
            event_driven: true,
            requeue_hold: SimTime::from_secs(60),
            user_core_limit: 4096,
            phase_seed: 0x5107_c10d,
            lua_plugin: false,
            scorer: Arc::new(NativeScorer),
        }
    }

    /// Builder: set the preemption approach.
    pub fn with_approach(mut self, approach: PreemptApproach) -> Self {
        self.approach = approach;
        self
    }

    /// Builder: set the phase seed (experiments vary this between runs).
    pub fn with_phase_seed(mut self, seed: u64) -> Self {
        self.phase_seed = seed;
        self
    }

    /// Builder: set the per-user interactive core limit.
    pub fn with_user_limit(mut self, cores: u32) -> Self {
        self.user_core_limit = cores;
        self
    }

    /// Builder: set the scoring backend.
    pub fn with_scorer(mut self, scorer: Arc<dyn PriorityScorer + Send + Sync>) -> Self {
        self.scorer = scorer;
        self
    }

    /// Builder: enable the Lua submit-plugin hook.
    pub fn with_lua_plugin(mut self, on: bool) -> Self {
        self.lua_plugin = on;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_chain() {
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
            .with_phase_seed(7)
            .with_user_limit(608)
            .with_lua_plugin(true);
        assert_eq!(cfg.phase_seed, 7);
        assert_eq!(cfg.user_core_limit, 608);
        assert!(cfg.lua_plugin);
        assert_eq!(cfg.scorer.name(), "native");
    }
}
