//! The PJRT/XLA runtime bridge.
//!
//! Loads the AOT-compiled scheduling decision module
//! (`artifacts/sched_step.hlo.txt`, built once by `make artifacts`) into the
//! PJRT CPU client and exposes it to the L3 scheduler hot path:
//!
//! * [`client`] — thin wrapper over the `xla` crate: HLO text → compile →
//!   execute.
//! * [`accel`] — [`accel::SchedAccel`]: the batched scheduling decision step
//!   (priority scores, LIFO preemption mask, fit counts) with padding to the
//!   AOT shape contract; implements [`crate::sched::PriorityScorer`].
//! * [`fallback`] — the pure-Rust implementation of the same math, used when
//!   artifacts are absent and as the equivalence oracle in tests.
//!
//! Python never runs at runtime: the artifact is self-contained HLO text.

pub mod accel;
pub mod client;
pub mod fallback;

pub use accel::{AccelOut, SchedAccel, ShapeContract};
pub use client::XlaModule;
