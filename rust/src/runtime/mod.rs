//! The PJRT/XLA runtime bridge.
//!
//! Loads the AOT-compiled scheduling decision module
//! (`artifacts/sched_step.hlo.txt`, built once by `make artifacts`) into the
//! PJRT CPU client and exposes it to the L3 scheduler hot path:
//!
//! * [`client`] — thin wrapper over the `xla` crate: HLO text → compile →
//!   execute (requires the `xla` cargo feature).
//! * [`accel`] — `SchedAccel`: the batched scheduling decision step
//!   (priority scores, LIFO preemption mask, fit counts) with padding to the
//!   AOT shape contract; implements [`crate::sched::PriorityScorer`]
//!   (requires the `xla` cargo feature).
//! * [`fallback`] — the pure-Rust implementation of the same math, used when
//!   artifacts are absent and as the equivalence oracle in tests.
//!
//! Python never runs at runtime: the artifact is self-contained HLO text.
//!
//! The `xla` binding crate is not vendored in this offline tree, so the
//! default build compiles a stub [`SchedAccel`] whose `load_default()`
//! always returns `None` — every caller already falls back to the native
//! scorer on that path. Enable the `xla` feature (and supply the binding
//! crate) to compile the real bridge.

#[cfg(feature = "xla")]
pub mod accel;
#[cfg(feature = "xla")]
pub mod client;
pub mod fallback;

#[cfg(feature = "xla")]
pub use accel::{AccelOut, SchedAccel, ShapeContract};
#[cfg(feature = "xla")]
pub use client::XlaModule;

#[cfg(not(feature = "xla"))]
mod stub {
    use crate::sched::priority::{JobFactors, PriorityScorer, WEIGHTS};

    /// Output of one decision step — mirrors `accel::AccelOut` so callers
    /// typecheck identically with or without the `xla` feature.
    #[derive(Debug, Clone, PartialEq)]
    pub struct AccelOut {
        /// Priority scores, one per input job.
        pub scores: Vec<f32>,
        /// LIFO preemption mask over the (youngest-first) spot jobs.
        pub preempt_mask: Vec<bool>,
        /// Feasible-node counts, one per input job.
        pub fit_counts: Vec<i32>,
    }

    /// Stub accelerator for builds without the `xla` feature: never loads,
    /// so callers always take their native-scorer fallback path. If a stub
    /// instance is ever constructed anyway (it cannot be, publicly), the
    /// methods degrade gracefully to the pure-Rust fallback math.
    pub struct SchedAccel {
        _private: (),
    }

    impl SchedAccel {
        /// Artifacts cannot be loaded without the `xla` feature.
        pub fn load_default() -> Option<Self> {
            None
        }

        /// Fallback-math equivalent of the compiled decision step.
        pub fn sched_step(
            &self,
            factors: &[JobFactors],
            spot_cores_youngest_first: &[f32],
            demand: f32,
            free: &[f32],
            reqs: &[f32],
        ) -> crate::util::error::Result<AccelOut> {
            Ok(AccelOut {
                scores: super::fallback::priority_scores(factors, &WEIGHTS),
                preempt_mask: super::fallback::select_victims(spot_cores_youngest_first, demand),
                fit_counts: super::fallback::fit_counts(free, reqs),
            })
        }
    }

    impl PriorityScorer for SchedAccel {
        fn scores(&self, factors: &[JobFactors]) -> Vec<f32> {
            super::fallback::priority_scores(factors, &WEIGHTS)
        }

        fn name(&self) -> &'static str {
            "xla-accel-stub"
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{AccelOut, SchedAccel};
