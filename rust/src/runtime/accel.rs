//! `SchedAccel`: the XLA-compiled scheduling decision step on the scheduler
//! hot path.
//!
//! Pads the scheduler's batches to the AOT shape contract
//! (`artifacts/sched_step.meta`), executes the compiled module, and unpads.
//! Implements [`PriorityScorer`] so `SchedulerConfig::with_scorer` can drop
//! it into the scheduling cycle. When the artifact is missing the caller
//! falls back to [`crate::runtime::fallback`] / [`NativeScorer`].

use super::client::{literal_f32, XlaModule};
use crate::ensure;
use crate::sched::priority::{JobFactors, PriorityScorer, N_FACTORS, WEIGHTS};
use crate::util::error::{Context, Result};
use std::path::Path;
use std::sync::Mutex;

/// The static shapes the artifact was compiled for (python/compile/model.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeContract {
    /// Max pending jobs per batch.
    pub jobs: usize,
    /// Priority factor width.
    pub factors: usize,
    /// Max running spot jobs.
    pub spots: usize,
    /// Max nodes.
    pub nodes: usize,
}

impl ShapeContract {
    /// Parse the `key=value` meta file written by `aot.py`.
    pub fn from_meta(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut jobs = None;
        let mut factors = None;
        let mut spots = None;
        let mut nodes = None;
        for line in text.lines() {
            let Some((k, v)) = line.split_once('=') else { continue };
            let v: usize = v.trim().parse().with_context(|| format!("bad meta line {line:?}"))?;
            match k.trim() {
                "jobs" => jobs = Some(v),
                "factors" => factors = Some(v),
                "spots" => spots = Some(v),
                "nodes" => nodes = Some(v),
                _ => {}
            }
        }
        Ok(Self {
            jobs: jobs.context("meta missing jobs")?,
            factors: factors.context("meta missing factors")?,
            spots: spots.context("meta missing spots")?,
            nodes: nodes.context("meta missing nodes")?,
        })
    }
}

/// Output of one accelerated decision step.
#[derive(Debug, Clone, PartialEq)]
pub struct AccelOut {
    /// Priority scores, one per input job.
    pub scores: Vec<f32>,
    /// LIFO preemption mask over the (youngest-first) spot jobs.
    pub preempt_mask: Vec<bool>,
    /// Feasible-node counts, one per input job.
    pub fit_counts: Vec<i32>,
}

/// The compiled decision module plus its shape contract.
///
/// Execution is serialized behind a mutex: PJRT executables are not
/// documented thread-safe through this binding, and the scheduler issues one
/// batch per cycle anyway.
pub struct SchedAccel {
    module: Mutex<XlaModule>,
    contract: ShapeContract,
}

// SAFETY: all access to the inner `XlaModule` goes through the `Mutex`,
// which serializes the non-atomic `Rc` refcount updates inside the xla
// binding (see the Send rationale on `XlaModule`).
unsafe impl Sync for SchedAccel {}

impl SchedAccel {
    /// Load from an artifact directory (`artifacts/`). Errors if the
    /// artifact or its meta file is missing or malformed.
    pub fn load(dir: &Path) -> Result<Self> {
        let contract = ShapeContract::from_meta(&dir.join("sched_step.meta"))?;
        ensure!(
            contract.factors == N_FACTORS,
            "artifact factor width {} != crate N_FACTORS {} — rebuild artifacts",
            contract.factors,
            N_FACTORS
        );
        let module = XlaModule::load(&dir.join("sched_step.hlo.txt"))?;
        Ok(Self {
            module: Mutex::new(module),
            contract,
        })
    }

    /// Load from the conventional location (`$CARGO_MANIFEST_DIR/artifacts`
    /// or `./artifacts`), returning `None` (not an error) when absent.
    pub fn load_default() -> Option<Self> {
        let candidates = [
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            std::path::PathBuf::from("artifacts"),
        ];
        for dir in candidates {
            if dir.join("sched_step.hlo.txt").exists() {
                match Self::load(&dir) {
                    Ok(a) => return Some(a),
                    Err(e) => {
                        eprintln!("warning: failed to load XLA artifact in {}: {e:#}", dir.display());
                        return None;
                    }
                }
            }
        }
        None
    }

    /// The shape contract.
    pub fn contract(&self) -> ShapeContract {
        self.contract
    }

    /// Run one decision step. Inputs longer than the contract are rejected
    /// (the scheduler chunks its batches).
    pub fn sched_step(
        &self,
        factors: &[JobFactors],
        spot_cores_youngest_first: &[f32],
        demand: f32,
        free: &[f32],
        reqs: &[f32],
    ) -> Result<AccelOut> {
        let c = self.contract;
        ensure!(factors.len() <= c.jobs, "too many jobs: {} > {}", factors.len(), c.jobs);
        ensure!(reqs.len() == factors.len(), "reqs/factors length mismatch");
        ensure!(
            spot_cores_youngest_first.len() <= c.spots,
            "too many spot jobs: {} > {}",
            spot_cores_youngest_first.len(),
            c.spots
        );
        ensure!(free.len() <= c.nodes, "too many nodes: {} > {}", free.len(), c.nodes);

        // Pad to the contract.
        let mut f = vec![0.0f32; c.jobs * c.factors];
        for (i, jf) in factors.iter().enumerate() {
            f[i * c.factors..(i + 1) * c.factors].copy_from_slice(&jf.0);
        }
        let mut spot = spot_cores_youngest_first.to_vec();
        spot.resize(c.spots, 0.0);
        let mut fr = free.to_vec();
        fr.resize(c.nodes, 0.0);
        let mut rq = reqs.to_vec();
        rq.resize(c.jobs, 1e18);

        let inputs = [
            literal_f32(&f, &[c.jobs as i64, c.factors as i64])?,
            literal_f32(&WEIGHTS, &[c.factors as i64])?,
            literal_f32(&spot, &[c.spots as i64])?,
            literal_f32(&[demand], &[1])?,
            literal_f32(&fr, &[c.nodes as i64])?,
            literal_f32(&rq, &[c.jobs as i64])?,
        ];
        let outs = self
            .module
            .lock()
            .expect("accel mutex poisoned")
            .execute(&inputs)?;
        ensure!(outs.len() == 3, "expected 3 outputs, got {}", outs.len());
        let scores_full = outs[0].to_vec::<f32>()?;
        let mask_full = outs[1].to_vec::<i32>()?;
        let counts_full = outs[2].to_vec::<i32>()?;
        Ok(AccelOut {
            scores: scores_full[..factors.len()].to_vec(),
            preempt_mask: mask_full[..spot_cores_youngest_first.len()]
                .iter()
                .map(|&m| m != 0)
                .collect(),
            fit_counts: counts_full[..factors.len()].to_vec(),
        })
    }
}

impl PriorityScorer for SchedAccel {
    fn scores(&self, factors: &[JobFactors]) -> Vec<f32> {
        if factors.is_empty() {
            return Vec::new();
        }
        // Chunk oversized queues to the contract.
        let c = self.contract;
        let mut out = Vec::with_capacity(factors.len());
        for chunk in factors.chunks(c.jobs) {
            let reqs = vec![1e18f32; chunk.len()];
            match self.sched_step(chunk, &[], 0.0, &[], &reqs) {
                Ok(r) => out.extend(r.scores),
                Err(e) => {
                    // Hot path must not fail: fall back to native scoring.
                    eprintln!("warning: accel scoring failed ({e:#}); using native fallback");
                    out.extend(super::fallback::priority_scores(chunk, &WEIGHTS));
                }
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "xla-accel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::fallback;
    use crate::util::rng::Xoshiro256;

    fn accel_or_skip() -> Option<SchedAccel> {
        match SchedAccel::load_default() {
            Some(a) => Some(a),
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                None
            }
        }
    }

    fn random_factors(rng: &mut Xoshiro256, n: usize) -> Vec<JobFactors> {
        (0..n)
            .map(|_| {
                let mut f = [0.0f32; N_FACTORS];
                for x in f.iter_mut() {
                    *x = rng.uniform(0.0, 10.0) as f32;
                }
                JobFactors(f)
            })
            .collect()
    }

    #[test]
    fn contract_matches_crate() {
        let Some(a) = accel_or_skip() else { return };
        assert_eq!(a.contract().factors, N_FACTORS);
        assert!(a.contract().jobs >= 512);
    }

    #[test]
    fn scores_match_fallback() {
        let Some(a) = accel_or_skip() else { return };
        let mut rng = Xoshiro256::new(42);
        let factors = random_factors(&mut rng, 300);
        let got = a.scores(&factors);
        let want = fallback::priority_scores(&factors, &WEIGHTS);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-2 * w.abs().max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn full_step_matches_fallback() {
        let Some(a) = accel_or_skip() else { return };
        let mut rng = Xoshiro256::new(7);
        let factors = random_factors(&mut rng, 50);
        let spot: Vec<f32> = (0..20).map(|_| rng.gen_range(0, 512) as f32).collect();
        let demand = 700.0f32;
        let free: Vec<f32> = (0..64).map(|_| rng.gen_range(0, 65) as f32).collect();
        let reqs: Vec<f32> = (0..50).map(|_| rng.gen_range(1, 64) as f32).collect();
        let out = a.sched_step(&factors, &spot, demand, &free, &reqs).unwrap();
        assert_eq!(out.preempt_mask, fallback::select_victims(&spot, demand));
        assert_eq!(out.fit_counts, fallback::fit_counts(&free, &reqs));
    }

    #[test]
    fn oversized_batch_chunks() {
        let Some(a) = accel_or_skip() else { return };
        let n = a.contract().jobs + 100;
        let mut rng = Xoshiro256::new(9);
        let factors = random_factors(&mut rng, n);
        let got = a.scores(&factors);
        assert_eq!(got.len(), n);
        let want = fallback::priority_scores(&factors, &WEIGHTS);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-2 * w.abs().max(1.0));
        }
    }

    #[test]
    fn empty_inputs() {
        let Some(a) = accel_or_skip() else { return };
        assert!(a.scores(&[]).is_empty());
        let out = a.sched_step(&[], &[], 0.0, &[], &[]).unwrap();
        assert!(out.scores.is_empty());
        assert!(out.preempt_mask.is_empty());
    }
}
