//! Pure-Rust implementation of the scheduling decision step.
//!
//! Semantically identical to the Pallas kernels (the pytest oracle in
//! `python/compile/kernels/ref.py` defines the contract). Used when the AOT
//! artifact is absent, and as the oracle for the accel equivalence tests.

use crate::sched::priority::{JobFactors, N_FACTORS};

/// `scores[j] = dot(factors[j], weights)`.
pub fn priority_scores(factors: &[JobFactors], weights: &[f32; N_FACTORS]) -> Vec<f32> {
    factors
        .iter()
        .map(|f| f.0.iter().zip(weights.iter()).map(|(x, w)| x * w).sum())
        .collect()
}

/// LIFO victim mask: minimal prefix of youngest-first `cores` covering
/// `demand`; zero entries are padding and never selected.
pub fn select_victims(cores_youngest_first: &[f32], demand: f32) -> Vec<bool> {
    let mut exclusive = 0.0f32;
    cores_youngest_first
        .iter()
        .map(|&c| {
            let selected = exclusive < demand && c > 0.0;
            exclusive += c;
            selected
        })
        .collect()
}

/// `counts[j] = #{m : free[m] >= reqs[j]}`.
pub fn fit_counts(free: &[f32], reqs: &[f32]) -> Vec<i32> {
    reqs.iter()
        .map(|&r| free.iter().filter(|&&f| f >= r).count() as i32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scores_dot_product() {
        let mut f = [0.0f32; N_FACTORS];
        f[0] = 2.0;
        f[1] = 3.0;
        let mut w = [0.0f32; N_FACTORS];
        w[0] = 10.0;
        w[1] = 1.0;
        let s = priority_scores(&[JobFactors(f)], &w);
        assert_eq!(s, vec![23.0]);
    }

    #[test]
    fn select_minimal_prefix() {
        let mask = select_victims(&[256.0, 128.0, 512.0], 300.0);
        assert_eq!(mask, vec![true, true, false]);
    }

    #[test]
    fn select_skips_padding_zeros() {
        let mask = select_victims(&[8.0, 0.0, 8.0], 16.0);
        assert_eq!(mask, vec![true, false, true]);
    }

    #[test]
    fn select_zero_demand() {
        let mask = select_victims(&[8.0, 8.0], 0.0);
        assert_eq!(mask, vec![false, false]);
    }

    #[test]
    fn fit_counts_basic() {
        let counts = fit_counts(&[0.0, 16.0, 32.0], &[16.0, 1e18]);
        assert_eq!(counts, vec![2, 0]);
    }
}
