//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange format is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reassigns
//! instruction ids, which sidesteps xla_extension 0.5.1's rejection of
//! jax ≥ 0.5's 64-bit-id protos.

use crate::util::error::{Context, Result};
use std::path::Path;

/// A compiled, executable XLA module on the PJRT CPU client.
pub struct XlaModule {
    exe: xla::PjRtLoadedExecutable,
    platform: String,
}

// SAFETY: the PJRT C++ client and loaded executable are thread-safe; the
// only thread-affine state in the Rust binding is the non-atomic `Rc`
// refcount inside `PjRtClient`. `XlaModule` owns the sole client handle and
// never hands out clones: refcount mutations happen only inside `execute`
// (buffers cloned and dropped before it returns) and at drop. Callers that
// share an `XlaModule` across threads must serialize access (SchedAccel
// wraps it in a `Mutex`), which also serializes those refcount updates.
unsafe impl Send for XlaModule {}

impl XlaModule {
    /// Load HLO text from `path`, compile it on a fresh CPU client.
    pub fn load(path: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let platform = client.platform_name();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text at {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Self { exe, platform })
    }

    /// PJRT platform name ("cpu" here; "tpu" with a TPU plugin).
    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Execute with literal inputs; returns the flattened tuple elements.
    /// The AOT pipeline lowers with `return_tuple=True`, so the single
    /// output buffer is a tuple literal we decompose.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self.exe.execute::<xla::Literal>(inputs).context("executing module")?;
        let first = outs
            .first()
            .and_then(|d| d.first())
            .context("executable produced no output buffer")?;
        let lit = first.to_literal_sync().context("fetching output literal")?;
        Ok(lit.to_tuple().context("decomposing output tuple")?)
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_path() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/sched_step.hlo.txt")
    }

    /// These tests require `make artifacts`; they skip (pass vacuously) when
    /// the artifact is absent so `cargo test` works on a fresh checkout.
    fn load_or_skip() -> Option<XlaModule> {
        let p = artifact_path();
        if !p.exists() {
            eprintln!("skipping: {} not built (run `make artifacts`)", p.display());
            return None;
        }
        Some(XlaModule::load(&p).expect("artifact should compile"))
    }

    #[test]
    fn loads_and_reports_platform() {
        let Some(m) = load_or_skip() else { return };
        assert_eq!(m.platform(), "cpu");
    }

    #[test]
    fn executes_with_correct_arity() {
        let Some(m) = load_or_skip() else { return };
        let jobs = 1024usize;
        let factors = literal_f32(&vec![0.0; jobs * 8], &[jobs as i64, 8]).unwrap();
        let weights = literal_f32(&[1.0; 8], &[8]).unwrap();
        let spot = literal_f32(&vec![0.0; 1024], &[1024]).unwrap();
        let demand = literal_f32(&[0.0], &[1]).unwrap();
        let free = literal_f32(&vec![0.0; 1024], &[1024]).unwrap();
        let reqs = literal_f32(&vec![1e18; 1024], &[1024]).unwrap();
        let outs = m
            .execute(&[factors, weights, spot, demand, free, reqs])
            .unwrap();
        assert_eq!(outs.len(), 3, "sched_step returns a 3-tuple");
        let scores = outs[0].to_vec::<f32>().unwrap();
        assert_eq!(scores.len(), jobs);
        assert!(scores.iter().all(|&s| s == 0.0));
    }

    #[test]
    fn missing_file_is_an_error() {
        let err = XlaModule::load(std::path::Path::new("/nonexistent/x.hlo.txt"));
        assert!(err.is_err());
    }
}
