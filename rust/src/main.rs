//! `spotcloud` — the command-line launcher.
//!
//! Subcommands:
//!
//! * `experiment <id|all>` — regenerate a paper figure/table (fig2a..fig2g,
//!   table1, ablations).
//! * `simulate` — run a mixed interactive+spot workload on a simulated
//!   cluster and print a utilization/latency report.
//! * `daemon` — start the coordinator daemon (TCP service).
//! * `submit | squeue | sjob | scancel | wait | stats | util | shutdown` —
//!   typed client commands against a running daemon (protocol v2, negotiated
//!   with `HELLO`; falls back to v1 output parsing transparently).

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::coordinator::{
    api, codec, journal, Client, ClientError, Daemon, DaemonConfig, DurabilityConfig, FsyncPolicy,
    Manifest, ManifestAck, ResumeInfo, RetryPolicy, Server, SqueueFilter, SubmitSpec,
};
use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::SchedulerConfig;
use spotcloud::sim::SchedCosts;
use spotcloud::util::cli::{CliError, Command};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("experiment") => cmd_experiment(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("daemon") => cmd_daemon(&args[1..]),
        Some(
            c @ ("submit" | "msubmit" | "squeue" | "sjob" | "scancel" | "wait" | "resume"
            | "stats" | "util" | "health" | "shutdown" | "ping"),
        ) => cmd_client(c, &args[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "spotcloud — Slurm-like scheduler with spot jobs via cron-agent preemption\n\
         (reproduction of Byun et al., HPEC 2020)\n\n\
         usage: spotcloud <subcommand> [options]\n\n\
         subcommands:\n\
           experiment <id|all>   regenerate a paper figure ({})\n\
           simulate              run a mixed workload simulation\n\
           daemon                start the coordinator daemon\n\
                                 (--journal <dir> enables the write-ahead journal; an existing\n\
                                  journal is replayed on start — crash recovery)\n\
           submit|msubmit|squeue|sjob|scancel|wait|resume|stats|util|health|ping|shutdown   client commands\n\
           (msubmit <file|->: one manifest entry per line, `qos=.. type=.. tasks=.. user=..\n\
            [cores_per_task=..] [run_secs=..] [count=..] [tag=..]`; # comments allowed)\n\
           (resume <tag> | resume --manifest <id>: re-attach after a crash or disconnect,\n\
            then wait out the entries that had not settled)\n\n\
         run `spotcloud <subcommand> --help` for options",
        spotcloud::experiments::ALL.join(", ")
    );
}

fn handle_help(cmd: &Command, err: CliError) -> i32 {
    match err {
        CliError::HelpRequested => {
            println!("{}", cmd.help());
            0
        }
        e => {
            eprintln!("error: {e}");
            2
        }
    }
}

fn cmd_experiment(args: &[String]) -> i32 {
    let cmd = Command::new("spotcloud experiment", "regenerate a paper figure/table")
        .positional("id", "experiment id (fig2a..fig2g, table1, ablations, all)")
        .opt("seed", "phase seed", Some("1"))
        .switch("csv", "also print CSV rows");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_help(&cmd, e),
    };
    let seed: u64 = parsed.value("seed").unwrap_or(1);
    let id = parsed
        .positionals
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let ids: Vec<&str> = if id == "all" {
        spotcloud::experiments::ALL.to_vec()
    } else {
        vec![id]
    };
    let mut ok = true;
    for id in ids {
        match spotcloud::experiments::run_by_id(id, seed) {
            Some(report) => {
                println!("{}", report.render());
                if parsed.flag("csv") {
                    println!("{}", report.to_csv());
                }
                ok &= report.check();
            }
            None => {
                eprintln!(
                    "unknown experiment {id:?}; available: {}",
                    spotcloud::experiments::ALL.join(", ")
                );
                return 2;
            }
        }
    }
    if ok {
        0
    } else {
        1
    }
}

fn cmd_simulate(args: &[String]) -> i32 {
    let cmd = Command::new("spotcloud simulate", "mixed interactive+spot workload simulation")
        .opt("seed", "workload seed", Some("7"))
        .opt("hours", "virtual hours to simulate", Some("2"))
        .opt("arrivals", "interactive submissions", Some("100"))
        .opt("reserve", "idle-node reserve for the cron agent", Some("5"))
        .switch("no-spot", "disable the spot backlog (baseline utilization)");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_help(&cmd, e),
    };
    let seed: u64 = parsed.value("seed").unwrap();
    let hours: u64 = parsed.value("hours").unwrap();
    let arrivals: usize = parsed.value("arrivals").unwrap();
    let reserve: u32 = parsed.value("reserve").unwrap();
    let spot = !parsed.flag("no-spot");
    let report = spotcloud::workload::simulate_mixed(seed, hours, arrivals, reserve, spot);
    println!("{report}");
    0
}

fn cmd_daemon(args: &[String]) -> i32 {
    let cmd = Command::new("spotcloud daemon", "start the coordinator daemon")
        .opt("addr", "bind address", Some("127.0.0.1:7461"))
        .opt("workers", "connection worker threads", Some("4"))
        .opt("shards", "reactor shards (SO_REUSEPORT listeners; Linux)", Some("1"))
        .opt("sched-shards", "partition scheduler shards (composes with --journal: one journal per shard)", Some("1"))
        .opt("speedup", "virtual seconds per wall second", Some("60"))
        .opt("reserve", "idle-node reserve (cron agent)", Some("5"))
        .opt("topology", "tx2500 | txgreen | txgreen-full", Some("tx2500"))
        .opt("config", "slurm.conf-style deployment file (overrides the above)", None)
        .opt("journal", "write-ahead journal directory (enables durability)", None)
        .opt("fsync", "journal sync policy: always | interval[:<n>] | never", Some("interval"))
        .opt("checkpoint-every", "journal records between checkpoints", Some("4096"))
        .switch("no-group-commit", "fsync=always: sync each append alone (no batched fsync)")
        .switch("xla", "use the XLA-compiled priority scorer (needs artifacts)");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_help(&cmd, e),
    };
    let addr: String = parsed.get("addr").unwrap().to_string();
    let workers: usize = parsed.value("workers").unwrap();
    let (Ok(shards), Ok(sched_shards)) = (
        parsed.value::<usize>("shards"),
        parsed.value::<usize>("sched-shards"),
    ) else {
        eprintln!("bad numeric option");
        return 2;
    };
    let speedup: f64 = parsed.value("speedup").unwrap();
    let reserve: u32 = parsed.value("reserve").unwrap();
    let (cluster, mut sched_cfg) = if let Some(path) = parsed.get("config") {
        match spotcloud::sched::deployment_from_file(std::path::Path::new(path)) {
            Ok(d) => {
                println!("loaded deployment {:?} from {path}", d.name);
                (d.cluster, d.config)
            }
            Err(e) => {
                eprintln!("failed to load {path}: {e:#}");
                return 2;
            }
        }
    } else {
        let cluster = match parsed.get("topology").unwrap() {
            "tx2500" => topology::tx2500(),
            "txgreen" => topology::txgreen_reservation(),
            "txgreen-full" => topology::txgreen_full(),
            other => {
                eprintln!("unknown topology {other:?}");
                return 2;
            }
        };
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
            .with_user_limit(reserve * cluster.cores_per_node())
            .with_approach(PreemptApproach::CronAgent {
                mode: PreemptMode::Requeue,
                cfg: CronAgentConfig {
                    reserve_nodes: reserve,
                },
            });
        (cluster, cfg)
    };
    if parsed.flag("xla") {
        match spotcloud::runtime::SchedAccel::load_default() {
            Some(accel) => {
                println!("loaded XLA decision kernel (platform: cpu)");
                sched_cfg = sched_cfg.with_scorer(Arc::new(accel));
            }
            None => {
                eprintln!("warning: artifacts not found, using native scorer (run `make artifacts`)");
            }
        }
    }
    let durability = match parsed.get("journal") {
        Some(dir) => {
            let fsync_s = parsed.get("fsync").unwrap();
            let Some(fsync) = FsyncPolicy::parse(fsync_s) else {
                eprintln!("bad --fsync {fsync_s:?} (always | interval[:<n>] | never)");
                return 2;
            };
            let every: u64 = match parsed.value("checkpoint-every") {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            Some(
                DurabilityConfig::new(dir)
                    .with_fsync(fsync)
                    .with_checkpoint_every(every)
                    .with_group_commit(!parsed.flag("no-group-commit")),
            )
        }
        None => None,
    };
    let journal_note = durability
        .as_ref()
        .map(|d| format!(", journal {} fsync={}", d.dir.display(), d.fsync.label()))
        .unwrap_or_default();
    let cfg = DaemonConfig {
        speedup,
        durability,
        shard_count: sched_shards.max(1),
        ..Default::default()
    };
    // A directory that already holds segments is a crashed (or cleanly
    // stopped) daemon's journal: replay it instead of refusing to boot.
    let recovering = cfg
        .durability
        .as_ref()
        .is_some_and(|d| journal::dir_has_segments(&d.dir));
    let daemon = if recovering {
        match Daemon::recover(cluster, sched_cfg, cfg) {
            Ok((daemon, report)) => {
                println!("{report}");
                daemon
            }
            Err(e) => {
                eprintln!("journal recovery failed: {e}");
                return 1;
            }
        }
    } else {
        // try_new surfaces boot-config problems (journal dir already holds
        // state, unusable dir) as a typed error instead of a panic.
        match Daemon::try_new(cluster, sched_cfg, cfg) {
            Ok(daemon) => daemon,
            Err(e) => {
                eprintln!("bad daemon config: {e}");
                return 2;
            }
        }
    };
    let pacer = daemon.spawn_pacer();
    let server = match Server::bind_sharded(Arc::clone(&daemon), &addr, workers, shards.max(1)) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to bind {addr}: {e:#}");
            return 1;
        }
    };
    println!(
        "spotcloud daemon listening on {} (speedup {speedup}x, reserve {reserve} nodes, \
         {} reactor shard(s), {} sched shard(s){journal_note})",
        server.local_addr().map(|a| a.to_string()).unwrap_or(addr),
        server.reactor_shards(),
        sched_shards.max(1),
    );
    server.serve();
    pacer.join().ok();
    println!("daemon stopped");
    0
}

fn cmd_client(subcmd: &str, args: &[String]) -> i32 {
    let cmd = Command::new("spotcloud client", "send a typed command to a running daemon")
        .opt("addr", "daemon address", Some("127.0.0.1:7461"))
        .opt("qos", "normal | spot (submit, squeue filter)", None)
        .opt("type", "individual | array | triple (submit)", Some("triple"))
        .opt("tasks", "task count (submit)", Some("64"))
        .opt("user", "user id (submit, squeue filter)", None)
        .opt("run-secs", "job run time (submit)", Some("600"))
        .opt("count", "batch count: copies of the spec in one RPC (submit)", Some("1"))
        .opt("state", "state filter (squeue)", None)
        .opt("limit", "row limit (squeue)", None)
        .opt("timeout", "wall timeout in seconds (wait, resume)", Some("30"))
        .opt("manifest", "manifest id to resume (alternative to a tag)", None)
        .opt("retries", "connection attempts before giving up (resume)", Some("5"))
        .opt("retry-base-ms", "backoff base delay in milliseconds (resume)", Some("100"))
        .positional("arg", "job id(s) for scancel / sjob / wait; manifest file (msubmit, - = stdin); tag (resume)");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_help(&cmd, e),
    };
    let addr = parsed.get("addr").unwrap();
    // `resume` exists to re-attach to a daemon that just crashed — give it
    // retry/backoff while the daemon restarts and replays its journal.
    // Every other command fails fast.
    let policy = if subcmd == "resume" {
        let (Ok(attempts), Ok(base_ms)) = (
            parsed.value::<u32>("retries"),
            parsed.value::<u64>("retry-base-ms"),
        ) else {
            eprintln!("bad numeric option");
            return 2;
        };
        RetryPolicy {
            attempts,
            base_delay: Duration::from_millis(base_ms),
            ..RetryPolicy::default()
        }
    } else {
        RetryPolicy::once()
    };
    let mut client = match Client::connect_v2_retry(addr, &policy) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("cannot reach daemon at {addr}: {e:#}");
            return 1;
        }
    };
    let job_ids = || -> Result<Vec<u64>, String> {
        let ids: Result<Vec<u64>, _> = parsed
            .positionals
            .iter()
            .map(|p| p.parse::<u64>().map_err(|_| format!("bad job id {p:?}")))
            .collect();
        let ids = ids?;
        if ids.is_empty() {
            return Err(format!("{subcmd} needs at least one job id"));
        }
        Ok(ids)
    };
    let outcome: Result<String, spotcloud::coordinator::ClientError> = match subcmd {
        "ping" => client.ping().map(|()| "pong".to_string()),
        "shutdown" => client.shutdown().map(|()| "shutting down".to_string()),
        "stats" => client.stats().map(render_stats),
        "util" => client.util().map(|u| u.to_string()),
        "health" => client.health().map(render_health),
        "submit" => {
            let qos = parsed.get("qos").unwrap_or("normal");
            let Some(qos) = api::parse_qos(qos) else {
                eprintln!("bad --qos {qos:?}");
                return 2;
            };
            let ty = parsed.get("type").unwrap();
            let Some(job_type) = api::parse_job_type(ty) else {
                eprintln!("bad --type {ty:?}");
                return 2;
            };
            let (Ok(tasks), Ok(user), Ok(run_secs), Ok(count)) = (
                parsed.value::<u32>("tasks"),
                parsed.value_opt::<u32>("user").map(|u| u.unwrap_or(1)),
                parsed.value::<f64>("run-secs"),
                parsed.value::<u32>("count"),
            ) else {
                eprintln!("bad numeric option");
                return 2;
            };
            client
                .submit(
                    &SubmitSpec::new(qos, job_type, tasks, user)
                        .with_run_secs(run_secs)
                        .with_count(count),
                )
                .map(|ack| ack.to_string())
        }
        "msubmit" => {
            let Some(path) = parsed.positionals.first() else {
                eprintln!("msubmit needs a manifest file path (or - for stdin)");
                return 2;
            };
            let text = if path == "-" {
                use std::io::Read as _;
                let mut buf = String::new();
                if let Err(e) = std::io::stdin().read_to_string(&mut buf) {
                    eprintln!("reading stdin: {e}");
                    return 2;
                }
                buf
            } else {
                match std::fs::read_to_string(path) {
                    Ok(t) => t,
                    Err(e) => {
                        eprintln!("reading {path}: {e}");
                        return 2;
                    }
                }
            };
            let mut entries = Vec::new();
            for (lineno, line) in text.lines().enumerate() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                match codec::parse_manifest_entry(line) {
                    Ok(e) => entries.push(e),
                    Err(e) => {
                        eprintln!("{path}:{}: {e}", lineno + 1);
                        return 2;
                    }
                }
            }
            client.msubmit(&Manifest { entries }).map(render_manifest_ack)
        }
        "squeue" => {
            let mut filter = SqueueFilter::default();
            match parsed.value_opt::<u32>("user") {
                Ok(u) => filter.user = u,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            }
            if let Some(q) = parsed.get("qos") {
                match api::parse_qos(q) {
                    Some(q) => filter.qos = Some(q),
                    None => {
                        eprintln!("bad --qos {q:?}");
                        return 2;
                    }
                }
            }
            if let Some(s) = parsed.get("state") {
                match api::parse_state(s) {
                    Some(s) => filter.state = Some(s),
                    None => {
                        eprintln!("bad --state {s:?}");
                        return 2;
                    }
                }
            }
            match parsed.value_opt::<usize>("limit") {
                Ok(l) => filter.limit = l,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            }
            client.squeue(&filter).map(render_squeue)
        }
        "sjob" => match job_ids() {
            Ok(ids) => client.job(ids[0]).map(render_job),
            Err(msg) => {
                eprintln!("{msg}");
                return 2;
            }
        },
        "scancel" => match job_ids() {
            Ok(ids) => client.cancel(ids[0]).map(|id| format!("cancelled {id}")),
            Err(msg) => {
                eprintln!("{msg}");
                return 2;
            }
        },
        "wait" => match job_ids() {
            Ok(ids) => {
                let timeout: f64 = match parsed.value("timeout") {
                    Ok(t) => t,
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                };
                client.wait(&ids, timeout).map(|w| w.to_string())
            }
            Err(msg) => {
                eprintln!("{msg}");
                return 2;
            }
        },
        "resume" => {
            let timeout: f64 = match parsed.value("timeout") {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let manifest_id = match parsed.value_opt::<u64>("manifest") {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let info = match (manifest_id, parsed.positionals.first()) {
                (Some(id), None) => client.resume_by_manifest(id),
                (None, Some(tag)) => client.resume_by_tag(tag),
                _ => {
                    eprintln!("resume needs exactly one of <tag> or --manifest <id>");
                    return 2;
                }
            };
            info.and_then(|info| run_resume(&mut client, &info, timeout))
        }
        other => {
            eprintln!("unknown client command {other:?}");
            return 2;
        }
    };
    match outcome {
        Ok(text) => {
            println!("{text}");
            0
        }
        Err(e) => {
            eprintln!("request failed: {e}");
            1
        }
    }
}

/// Render a resume and wait out the not-yet-settled entries: the crash/
/// reconnect workflow end to end — re-attach, see what survived, block on
/// the rest.
fn run_resume(client: &mut Client, info: &ResumeInfo, timeout: f64) -> Result<String, ClientError> {
    let mut out = info.to_string();
    for e in &info.entries {
        out.push_str(&format!(
            "\n  entry {}: jobs {}-{} settled {}/{}{}",
            e.index,
            e.first,
            e.first + e.count.saturating_sub(1),
            e.settled,
            e.count,
            e.tag
                .as_deref()
                .map(|t| format!(" tag={t}"))
                .unwrap_or_default(),
        ));
    }
    let pending: Vec<u32> = info.pending_entries().map(|e| e.index).collect();
    for idx in pending {
        let w = client.wait_entry(info.manifest, idx, timeout)?;
        out.push_str(&format!("\n  entry {idx}: {w}"));
    }
    Ok(out)
}

fn render_manifest_ack(ack: ManifestAck) -> String {
    let mut out = format!("manifest {ack}");
    if let Some(id) = ack.manifest {
        out.push_str(&format!(
            " [id {id} — re-attach with `spotcloud resume --manifest {id}`]"
        ));
    }
    for acc in &ack.accepted {
        out.push_str(&format!(
            "\n  entry {}: accepted, jobs {}-{} ({} job{})",
            acc.index,
            acc.first,
            acc.last,
            acc.count,
            if acc.count == 1 { "" } else { "s" },
        ));
    }
    for rej in &ack.rejected {
        out.push_str(&format!(
            "\n  entry {}: REJECTED [{}] {}",
            rej.index, rej.error.code, rej.error.message
        ));
    }
    out
}

fn render_squeue(rows: Vec<spotcloud::coordinator::JobSummary>) -> String {
    let mut out = String::from("JOBID TYPE TASKS USER QOS STATE TAG");
    for r in &rows {
        out.push_str(&format!(
            "\n{} {} {} user{} {} {} {}",
            r.id,
            r.job_type.label(),
            r.tasks,
            r.user,
            r.qos,
            api::state_token(r.state),
            r.tag.as_deref().unwrap_or("-"),
        ));
    }
    out.push_str(&format!("\n({} jobs)", rows.len()));
    out
}

fn render_job(d: spotcloud::coordinator::JobDetail) -> String {
    let opt = |v: Option<f64>| v.map(|x| format!("{x:.3}s")).unwrap_or_else(|| "-".into());
    format!(
        "job {} {} tasks={} user{} qos={} state={} tag={} submitted={:.3}s started={} ended={} \
         requeues={} sched_latency={}",
        d.id,
        d.job_type.label(),
        d.tasks,
        d.user,
        d.qos,
        api::state_token(d.state),
        d.tag.as_deref().unwrap_or("-"),
        d.submit_secs,
        opt(d.start_secs),
        opt(d.end_secs),
        d.requeues,
        d.latency_ns
            .map(|ns| format!("{:.3}s", ns as f64 / 1e9))
            .unwrap_or_else(|| "-".into()),
    )
}

fn render_health(h: spotcloud::coordinator::HealthReport) -> String {
    format!(
        "state={} since={:.1}s inflight={}/{}\n\
         shed: submits={} msubmits={} rate_limited={} deadline_expired={} conns_evicted={}\n\
         journal_poisoned={}",
        h.state,
        h.since_secs,
        h.inflight,
        if h.inflight_budget == 0 {
            "unbounded".to_string()
        } else {
            h.inflight_budget.to_string()
        },
        h.shed_submits,
        h.shed_msubmits,
        h.rate_limited,
        h.deadline_expired,
        h.conns_evicted,
        h.journal_poisoned,
    )
}

fn render_stats(s: spotcloud::coordinator::StatsSnapshot) -> String {
    let commands = s
        .commands
        .iter()
        .filter(|&(_, &n)| n > 0)
        .map(|(cmd, n)| format!("{cmd}={n}"))
        .collect::<Vec<_>>()
        .join(" ");
    let contention = s
        .contention
        .map(|c| {
            format!(
                "\ncontention: reads={} write_locks={} waits={}/{} | lock hold: n={} \
                 p50={}ns p99={}ns max={}ns",
                c.read_path_ops,
                c.write_locks,
                c.waits_resumed,
                c.waits_parked,
                c.lock_hold_count,
                c.lock_hold_p50_ns,
                c.lock_hold_p99_ns,
                c.lock_hold_max_ns,
            )
        })
        .unwrap_or_default();
    let journal = s
        .journal
        .map(|j| {
            format!(
                "\njournal: appends={} synced={} group_commits={} poisoned={}",
                j.appends, j.synced_appends, j.group_commits, j.poisoned,
            )
        })
        .unwrap_or_default();
    let health = s
        .health
        .map(|h| {
            format!(
                "\nhealth: state={} inflight={} shed_submits={} shed_msubmits={} \
                 rate_limited={} deadline_expired={} conns_evicted={}",
                h.state,
                h.inflight,
                h.shed_submits,
                h.shed_msubmits,
                h.rate_limited,
                h.deadline_expired,
                h.conns_evicted,
            )
        })
        .unwrap_or_default();
    let users = s
        .users
        .map(|u| {
            format!(
                "\nusers: active={} tracked={} buckets_live={}",
                u.users_active, u.users_tracked, u.buckets_live,
            )
        })
        .unwrap_or_default();
    let shards = if s.shards.is_empty() {
        String::new()
    } else {
        let mut t = String::from("\nshards: KIND IDX LABEL WAKEUPS EVENTS CONNS PARKED QDEPTH P99NS");
        for sh in &s.shards {
            t.push_str(&format!(
                "\n  {} {} {} {} {} {} {} {} {}",
                sh.kind.as_str(),
                sh.index,
                sh.label,
                sh.wakeups,
                sh.events,
                sh.connections,
                sh.parked,
                sh.queue_depth,
                sh.lock_hold_p99_ns,
            ));
        }
        t
    };
    format!(
        "virtual_now={:.1}s dispatches={} preemptions={} requeues={} cron_passes={} \
         main_passes={} backfill_passes={} triggered_passes={} scorer={}\n\
         requests: ok={} err={} jobs_submitted={} | sched latency: n={} p50={:.3}s\n\
         commands: {commands}{contention}{journal}{health}{users}{shards}",
        s.virtual_now_secs,
        s.dispatches,
        s.preemptions,
        s.requeues,
        s.cron_passes,
        s.main_passes,
        s.backfill_passes,
        s.triggered_passes,
        s.scorer,
        s.requests_ok,
        s.requests_err,
        s.jobs_submitted,
        s.sched_latency_count,
        s.sched_latency_p50_ns as f64 / 1e9,
    )
}
