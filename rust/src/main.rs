//! `spotcloud` — the command-line launcher.
//!
//! Subcommands:
//!
//! * `experiment <id|all>` — regenerate a paper figure/table (fig2a..fig2g,
//!   table1, ablations).
//! * `simulate` — run a mixed interactive+spot workload on a simulated
//!   cluster and print a utilization/latency report.
//! * `daemon` — start the coordinator daemon (TCP service).
//! * `submit | squeue | scancel | stats | util | shutdown` — client commands
//!   against a running daemon.

use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::coordinator::{client::Client, Daemon, DaemonConfig, Server};
use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::SchedulerConfig;
use spotcloud::sim::SchedCosts;
use spotcloud::util::cli::{CliError, Command};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("experiment") => cmd_experiment(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("daemon") => cmd_daemon(&args[1..]),
        Some(c @ ("submit" | "squeue" | "scancel" | "stats" | "util" | "shutdown" | "ping")) => {
            cmd_client(c, &args[1..])
        }
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "spotcloud — Slurm-like scheduler with spot jobs via cron-agent preemption\n\
         (reproduction of Byun et al., HPEC 2020)\n\n\
         usage: spotcloud <subcommand> [options]\n\n\
         subcommands:\n\
           experiment <id|all>   regenerate a paper figure ({})\n\
           simulate              run a mixed workload simulation\n\
           daemon                start the coordinator daemon\n\
           submit|squeue|scancel|stats|util|ping|shutdown   client commands\n\n\
         run `spotcloud <subcommand> --help` for options",
        spotcloud::experiments::ALL.join(", ")
    );
}

fn handle_help(cmd: &Command, err: CliError) -> i32 {
    match err {
        CliError::HelpRequested => {
            println!("{}", cmd.help());
            0
        }
        e => {
            eprintln!("error: {e}");
            2
        }
    }
}

fn cmd_experiment(args: &[String]) -> i32 {
    let cmd = Command::new("spotcloud experiment", "regenerate a paper figure/table")
        .positional("id", "experiment id (fig2a..fig2g, table1, ablations, all)")
        .opt("seed", "phase seed", Some("1"))
        .switch("csv", "also print CSV rows");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_help(&cmd, e),
    };
    let seed: u64 = parsed.value("seed").unwrap_or(1);
    let id = parsed
        .positionals
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let ids: Vec<&str> = if id == "all" {
        spotcloud::experiments::ALL.to_vec()
    } else {
        vec![id]
    };
    let mut ok = true;
    for id in ids {
        match spotcloud::experiments::run_by_id(id, seed) {
            Some(report) => {
                println!("{}", report.render());
                if parsed.flag("csv") {
                    println!("{}", report.to_csv());
                }
                ok &= report.check();
            }
            None => {
                eprintln!(
                    "unknown experiment {id:?}; available: {}",
                    spotcloud::experiments::ALL.join(", ")
                );
                return 2;
            }
        }
    }
    if ok {
        0
    } else {
        1
    }
}

fn cmd_simulate(args: &[String]) -> i32 {
    let cmd = Command::new("spotcloud simulate", "mixed interactive+spot workload simulation")
        .opt("seed", "workload seed", Some("7"))
        .opt("hours", "virtual hours to simulate", Some("2"))
        .opt("arrivals", "interactive submissions", Some("100"))
        .opt("reserve", "idle-node reserve for the cron agent", Some("5"))
        .switch("no-spot", "disable the spot backlog (baseline utilization)");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_help(&cmd, e),
    };
    let seed: u64 = parsed.value("seed").unwrap();
    let hours: u64 = parsed.value("hours").unwrap();
    let arrivals: usize = parsed.value("arrivals").unwrap();
    let reserve: u32 = parsed.value("reserve").unwrap();
    let spot = !parsed.flag("no-spot");
    let report = spotcloud::workload::simulate_mixed(seed, hours, arrivals, reserve, spot);
    println!("{report}");
    0
}

fn cmd_daemon(args: &[String]) -> i32 {
    let cmd = Command::new("spotcloud daemon", "start the coordinator daemon")
        .opt("addr", "bind address", Some("127.0.0.1:7461"))
        .opt("workers", "connection worker threads", Some("4"))
        .opt("speedup", "virtual seconds per wall second", Some("60"))
        .opt("reserve", "idle-node reserve (cron agent)", Some("5"))
        .opt("topology", "tx2500 | txgreen | txgreen-full", Some("tx2500"))
        .opt("config", "slurm.conf-style deployment file (overrides the above)", None)
        .switch("xla", "use the XLA-compiled priority scorer (needs artifacts)");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_help(&cmd, e),
    };
    let addr: String = parsed.get("addr").unwrap().to_string();
    let workers: usize = parsed.value("workers").unwrap();
    let speedup: f64 = parsed.value("speedup").unwrap();
    let reserve: u32 = parsed.value("reserve").unwrap();
    let (cluster, mut sched_cfg) = if let Some(path) = parsed.get("config") {
        match spotcloud::sched::deployment_from_file(std::path::Path::new(path)) {
            Ok(d) => {
                println!("loaded deployment {:?} from {path}", d.name);
                (d.cluster, d.config)
            }
            Err(e) => {
                eprintln!("failed to load {path}: {e:#}");
                return 2;
            }
        }
    } else {
        let cluster = match parsed.get("topology").unwrap() {
            "tx2500" => topology::tx2500(),
            "txgreen" => topology::txgreen_reservation(),
            "txgreen-full" => topology::txgreen_full(),
            other => {
                eprintln!("unknown topology {other:?}");
                return 2;
            }
        };
        let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
            .with_user_limit(reserve * cluster.cores_per_node())
            .with_approach(PreemptApproach::CronAgent {
                mode: PreemptMode::Requeue,
                cfg: CronAgentConfig {
                    reserve_nodes: reserve,
                },
            });
        (cluster, cfg)
    };
    if parsed.flag("xla") {
        match spotcloud::runtime::SchedAccel::load_default() {
            Some(accel) => {
                println!("loaded XLA decision kernel (platform: cpu)");
                sched_cfg = sched_cfg.with_scorer(Arc::new(accel));
            }
            None => {
                eprintln!("warning: artifacts not found, using native scorer (run `make artifacts`)");
            }
        }
    }
    let daemon = Daemon::new(
        cluster,
        sched_cfg,
        DaemonConfig {
            speedup,
            ..Default::default()
        },
    );
    let pacer = daemon.spawn_pacer();
    let server = match Server::bind(Arc::clone(&daemon), &addr, workers) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to bind {addr}: {e:#}");
            return 1;
        }
    };
    println!(
        "spotcloud daemon listening on {} (speedup {speedup}x, reserve {reserve} nodes)",
        server.local_addr().map(|a| a.to_string()).unwrap_or(addr)
    );
    server.serve();
    pacer.join().ok();
    println!("daemon stopped");
    0
}

fn cmd_client(subcmd: &str, args: &[String]) -> i32 {
    let cmd = Command::new("spotcloud client", "send a command to a running daemon")
        .opt("addr", "daemon address", Some("127.0.0.1:7461"))
        .opt("qos", "normal | spot (submit)", Some("normal"))
        .opt("type", "individual | array | triple (submit)", Some("triple"))
        .opt("tasks", "task count (submit)", Some("64"))
        .opt("user", "user id (submit)", Some("1"))
        .opt("run-secs", "job run time (submit)", Some("600"))
        .positional("arg", "job id for scancel");
    let parsed = match cmd.parse(args) {
        Ok(p) => p,
        Err(e) => return handle_help(&cmd, e),
    };
    let addr = parsed.get("addr").unwrap();
    let mut client = match Client::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("cannot reach daemon at {addr}: {e:#}");
            return 1;
        }
    };
    let line = match subcmd {
        "submit" => format!(
            "SUBMIT {} {} {} {} {}",
            parsed.get("qos").unwrap(),
            parsed.get("type").unwrap(),
            parsed.get("tasks").unwrap(),
            parsed.get("user").unwrap(),
            parsed.get("run-secs").unwrap()
        ),
        "scancel" => match parsed.positionals.first() {
            Some(id) => format!("SCANCEL {id}"),
            None => {
                eprintln!("scancel needs a job id");
                return 2;
            }
        },
        other => other.to_ascii_uppercase(),
    };
    match client.request(&line) {
        Ok(resp) => {
            println!("{resp}");
            if resp.starts_with("ERR") {
                1
            } else {
                0
            }
        }
        Err(e) => {
            eprintln!("request failed: {e:#}");
            1
        }
    }
}
