//! Submission traces: CSV record/replay.
//!
//! Lets the daemon record live workloads and lets experiments replay
//! identical submission sequences across configurations.

use crate::job::{JobSpec, JobType, QosClass, UserId};
use crate::sim::SimTime;
use crate::util::error::Result;
use crate::{bail, ensure, err_msg};

/// One trace line.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// Submission time (seconds from trace start).
    pub at_secs: f64,
    /// Submitting user.
    pub user: u32,
    /// Launch type.
    pub job_type: JobType,
    /// Total tasks.
    pub tasks: u32,
    /// QoS class.
    pub qos: QosClass,
    /// Run time in seconds.
    pub run_secs: f64,
}

impl TraceRecord {
    /// Convert to a JobSpec (individual records stay single-task; expansion
    /// happens at submission time).
    pub fn to_spec(&self) -> JobSpec {
        let base = match self.qos {
            QosClass::Normal => JobSpec::interactive(UserId(self.user), self.job_type, self.tasks),
            QosClass::Spot => JobSpec::spot(UserId(self.user), self.job_type, self.tasks),
        };
        base.with_run_time(SimTime::from_secs_f64(self.run_secs))
    }
}

/// A submission trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Records in time order.
    pub records: Vec<TraceRecord>,
}

fn type_label(t: JobType) -> &'static str {
    match t {
        JobType::Individual => "individual",
        JobType::Array => "array",
        JobType::TripleMode => "triple",
    }
}

fn parse_type(s: &str) -> Option<JobType> {
    match s {
        "individual" => Some(JobType::Individual),
        "array" => Some(JobType::Array),
        "triple" => Some(JobType::TripleMode),
        _ => None,
    }
}

impl Trace {
    /// Serialize to CSV (with header).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("at_secs,user,job_type,tasks,qos,run_secs\n");
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                r.at_secs,
                r.user,
                type_label(r.job_type),
                r.tasks,
                r.qos.label(),
                r.run_secs
            ));
        }
        out
    }

    /// Parse from CSV text.
    pub fn from_csv(text: &str) -> Result<Self> {
        let mut records = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue; // header / blanks
            }
            let cols: Vec<&str> = line.split(',').collect();
            ensure!(cols.len() == 6, "line {}: expected 6 columns", i + 1);
            records.push(TraceRecord {
                at_secs: cols[0].parse()?,
                user: cols[1].parse()?,
                job_type: parse_type(cols[2])
                    .ok_or_else(|| err_msg!("line {}: bad job type {:?}", i + 1, cols[2]))?,
                tasks: cols[3].parse()?,
                qos: match cols[4] {
                    "normal" => QosClass::Normal,
                    "spot" => QosClass::Spot,
                    other => bail!("line {}: bad qos {other:?}", i + 1),
                },
                run_secs: cols[5].parse()?,
            });
        }
        Ok(Trace { records })
    }

    /// Write to a file.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_csv(&std::fs::read_to_string(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        Trace {
            records: vec![
                TraceRecord {
                    at_secs: 0.5,
                    user: 1,
                    job_type: JobType::TripleMode,
                    tasks: 4096,
                    qos: QosClass::Normal,
                    run_secs: 600.0,
                },
                TraceRecord {
                    at_secs: 2.0,
                    user: 9,
                    job_type: JobType::Array,
                    tasks: 128,
                    qos: QosClass::Spot,
                    run_secs: 86400.0,
                },
            ],
        }
    }

    #[test]
    fn csv_roundtrip() {
        let t = sample();
        let parsed = Trace::from_csv(&t.to_csv()).unwrap();
        assert_eq!(t, parsed);
    }

    #[test]
    fn to_spec_maps_qos() {
        let t = sample();
        assert_eq!(t.records[0].to_spec().qos, QosClass::Normal);
        assert_eq!(t.records[1].to_spec().qos, QosClass::Spot);
    }

    #[test]
    fn bad_csv_rejected() {
        assert!(Trace::from_csv("h\n1,2,3\n").is_err());
        assert!(Trace::from_csv("h\n1,1,warp,64,normal,5\n").is_err());
        assert!(Trace::from_csv("h\n1,1,array,64,superfast,5\n").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let t = sample();
        let path = std::env::temp_dir().join("spotcloud_trace_test.csv");
        t.save(&path).unwrap();
        assert_eq!(Trace::load(&path).unwrap(), t);
        let _ = std::fs::remove_file(&path);
    }
}
