//! Workload synthesis: the paper's experiment scenarios plus stochastic
//! generators for the end-to-end daemon driver.

pub mod gen;
pub mod manifests;
pub mod scenarios;
pub mod sim_mixed;
pub mod trace;

pub use gen::{WorkloadGen, WorkloadGenConfig};
pub use scenarios::{interactive_burst, spot_fill, Scenario};
pub use sim_mixed::{simulate_mixed, MixedReport};
pub use trace::{Trace, TraceRecord};
