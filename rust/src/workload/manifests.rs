//! The paper's workload shapes as submission manifests.
//!
//! [`crate::workload::scenarios`] builds `Vec<JobSpec>` for the in-process
//! simulator; this module builds the same shapes as typed
//! [`Manifest`]s so they can be replayed **against a running daemon over
//! TCP** through the public client (`Client::msubmit`) — the live
//! Figure-2 mode in [`crate::experiments::live`] and the
//! `manifest_scaling` bench both draw from here.

use std::sync::Arc;

use crate::coordinator::manifest::{Manifest, ManifestBuilder, ManifestEntry, MAX_MANIFEST_ENTRIES};
use crate::job::{JobType, QosClass};
use crate::util::rng::{Xoshiro256, Zipf};

/// The interactive Figure-2 burst as a one-entry manifest: exactly what
/// [`crate::workload::interactive_burst`] submits (an *individual* entry
/// expands daemon-side into `tasks` one-task jobs).
pub fn fig2_burst(user: u32, job_type: JobType, tasks: u32, run_secs: f64) -> Manifest {
    ManifestBuilder::new()
        .entry(
            ManifestEntry::new(QosClass::Normal, job_type, tasks, user)
                .with_run_secs(run_secs)
                .with_tag("fig2-live"),
        )
        .build()
}

/// The spot fill as a manifest: `n_jobs` long triple-mode spot entries
/// covering `total_tasks` in aggregate (mirrors
/// [`crate::workload::spot_fill`]).
pub fn spot_fill(user: u32, total_tasks: u32, n_jobs: u32) -> Manifest {
    assert!(n_jobs > 0);
    let per = total_tasks / n_jobs;
    let mut b = ManifestBuilder::new();
    let mut remaining = total_tasks;
    for i in 0..n_jobs {
        let t = if i + 1 == n_jobs { remaining } else { per };
        remaining -= t;
        if t > 0 {
            b = b.entry(
                ManifestEntry::new(QosClass::Spot, JobType::TripleMode, t, user)
                    .with_run_secs(30.0 * 24.0 * 3600.0)
                    .with_tag("spot-fill"),
            );
        }
    }
    b.build()
}

/// A deterministic heterogeneous manifest in the paper's mixture shape:
/// `entries` entries cycling through all three launch types, interactive
/// and spot QoS, and `users` distinct users. Every entry materializes
/// **exactly one job** (individual entries use `tasks=1`), so an
/// `entries`-entry manifest is directly comparable to a homogeneous
/// `count=entries` burst — the `manifest_scaling` bench's equivalence.
pub fn mixed(seed: u64, entries: usize, users: u32) -> Manifest {
    assert!(users >= 1);
    let mut rng = Xoshiro256::new(seed);
    let mut b = ManifestBuilder::new();
    for i in 0..entries {
        let user = 1 + rng.gen_range(0, users as u64) as u32;
        let jt = match i % 3 {
            0 => JobType::Individual,
            1 => JobType::Array,
            _ => JobType::TripleMode,
        };
        let tasks = match jt {
            JobType::Individual => 1,
            _ => 1 + rng.gen_range(0, 8) as u32,
        };
        let entry = if i % 4 == 0 {
            ManifestEntry::new(QosClass::Spot, jt, tasks, 100 + user)
                .with_run_secs(86_400.0)
                .with_tag("mixed-spot")
        } else {
            ManifestEntry::new(QosClass::Normal, jt, tasks, user)
                .with_run_secs(600.0)
                .with_tag("mixed-interactive")
        };
        b = b.entry(entry);
    }
    b.build()
}

/// Everything about a manifest entry *except* the submitting user: the
/// reusable half of a trace record. A replay supplies the user per
/// instantiation — [`zipf_user_manifests`] stamps templates with
/// Zipf-sampled users, a recorded trace would stamp them with the users
/// it captured.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestTemplate {
    /// QoS class every instantiation carries.
    pub qos: QosClass,
    /// Launch type every instantiation carries.
    pub job_type: JobType,
    /// Task count per instantiation.
    pub tasks: u32,
    /// Requested runtime in seconds.
    pub run_secs: f64,
    /// Optional correlation tag shared by all instantiations.
    pub tag: Option<Arc<str>>,
}

impl ManifestTemplate {
    /// A template with no tag; chain [`Self::with_tag`] to add one.
    pub fn new(qos: QosClass, job_type: JobType, tasks: u32, run_secs: f64) -> Self {
        Self {
            qos,
            job_type,
            tasks,
            run_secs,
            tag: None,
        }
    }

    /// Attach a correlation tag.
    pub fn with_tag(mut self, tag: impl Into<Arc<str>>) -> Self {
        self.tag = Some(tag.into());
        self
    }

    /// The interactive probe shape: one `Normal` individual task, ten
    /// minutes. Exactly one job per instantiation, so entry counts and
    /// job counts stay interchangeable in scaling benches.
    pub fn interactive_probe() -> Self {
        Self::new(QosClass::Normal, JobType::Individual, 1, 600.0).with_tag("user-probe")
    }

    /// The spot filler shape: one long `Spot` triple-mode entry (which
    /// also materializes exactly one job regardless of `tasks`).
    pub fn spot_filler() -> Self {
        Self::new(QosClass::Spot, JobType::TripleMode, 4, 86_400.0).with_tag("user-filler")
    }

    /// Stamp the template with a user, yielding a concrete entry.
    pub fn instantiate(&self, user: u32) -> ManifestEntry {
        let e = ManifestEntry::new(self.qos, self.job_type, self.tasks, user)
            .with_run_secs(self.run_secs);
        match &self.tag {
            Some(t) => e.with_tag(Arc::clone(t)),
            None => e,
        }
    }
}

/// Pack a stream of entries into wire-submittable manifests of at most
/// [`MAX_MANIFEST_ENTRIES`] entries each.
fn chunked(entries: impl Iterator<Item = ManifestEntry>) -> Vec<Manifest> {
    let mut out = Vec::new();
    let mut b = ManifestBuilder::new();
    for e in entries {
        b = b.entry(e);
        if b.len() == MAX_MANIFEST_ENTRIES {
            out.push(std::mem::replace(&mut b, ManifestBuilder::new()).build());
        }
    }
    if !b.is_empty() {
        out.push(b.build());
    }
    out
}

/// A heavy-tail replay trace: `entries` template instantiations whose
/// users are Zipf(`exponent`)-distributed ranks over `1..=users`,
/// cycling through `templates`, packed into ≤[`MAX_MANIFEST_ENTRIES`]
/// manifests. Deterministic in `seed`.
pub fn zipf_user_manifests(
    seed: u64,
    users: u64,
    entries: usize,
    exponent: f64,
    templates: &[ManifestTemplate],
) -> Vec<Manifest> {
    assert!(!templates.is_empty(), "zipf_user_manifests: no templates");
    let zipf = Zipf::new(users, exponent);
    let mut rng = Xoshiro256::new(seed);
    chunked((0..entries).map(|i| {
        let user = zipf.sample(&mut rng) as u32;
        templates[i % templates.len()].instantiate(user)
    }))
}

/// The user-cardinality scaling workload: one entry from **every** user
/// `1..=users` (so the level's distinct-user count is exact, not a
/// sampling accident) followed by `users / 4` Zipf-sampled hot extras
/// that concentrate repeat traffic on low ranks the way production
/// submitters do. Templates alternate interactive probe / spot filler,
/// both of which materialize exactly one job per entry, so per-job and
/// per-entry costs coincide. Deterministic in `seed`.
pub fn user_scaling_manifests(seed: u64, users: u64, exponent: f64) -> Vec<Manifest> {
    assert!(users >= 1 && users <= u32::MAX as u64);
    let templates = [
        ManifestTemplate::interactive_probe(),
        ManifestTemplate::spot_filler(),
    ];
    let zipf = Zipf::new(users, exponent);
    let mut rng = Xoshiro256::new(seed);
    let extras = (users / 4) as usize;
    let sweep = (0..users as usize).map(|i| (i as u32 + 1, i));
    let hot = (0..extras).map(move |i| (zipf.sample(&mut rng) as u32, users as usize + i));
    chunked(
        sweep
            .chain(hot)
            .map(move |(user, i)| templates[i % templates.len()].instantiate(user)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_burst_matches_scenarios_expansion() {
        let m = fig2_burst(1, JobType::Individual, 608, 600.0);
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.jobs(), 608, "individual expands per task");
        let m = fig2_burst(1, JobType::TripleMode, 4096, 600.0);
        assert_eq!(m.jobs(), 1);
        assert!(m.entries.iter().all(|e| e.validate().is_ok()));
    }

    #[test]
    fn spot_fill_covers_total_like_scenarios() {
        let m = spot_fill(900, 4096, 8);
        assert_eq!(m.entries.len(), 8);
        assert_eq!(m.entries.iter().map(|e| e.tasks).sum::<u32>(), 4096);
        assert!(m.entries.iter().all(|e| e.qos == QosClass::Spot));
        let uneven = spot_fill(900, 100, 3);
        assert_eq!(uneven.entries.iter().map(|e| e.tasks).sum::<u32>(), 100);
    }

    #[test]
    fn mixed_is_deterministic_heterogeneous_and_one_job_per_entry() {
        let a = mixed(7, 1000, 5);
        let b = mixed(7, 1000, 5);
        assert_eq!(a, b, "same seed, same manifest");
        assert_eq!(a.entries.len(), 1000);
        assert_eq!(a.jobs(), 1000, "one job per entry");
        assert!(a.entries.iter().all(|e| e.validate().is_ok()));
        let types: std::collections::BTreeSet<_> =
            a.entries.iter().map(|e| e.job_type.label()).collect();
        assert_eq!(types.len(), 3, "all three launch types present");
        assert!(a.entries.iter().any(|e| e.qos == QosClass::Spot));
        assert!(a.entries.iter().any(|e| e.qos == QosClass::Normal));
        let users: std::collections::BTreeSet<_> = a.entries.iter().map(|e| e.user).collect();
        assert!(users.len() >= 3, "{users:?}");
    }

    #[test]
    fn template_instantiation_is_valid_and_one_job() {
        for t in [
            ManifestTemplate::interactive_probe(),
            ManifestTemplate::spot_filler(),
        ] {
            let e = t.instantiate(42);
            assert_eq!(e.user, 42);
            assert_eq!(e.jobs(), 1, "scaling templates are one job per entry");
            assert!(e.validate().is_ok(), "{e:?}");
            assert!(e.tag.is_some());
        }
        let bare = ManifestTemplate::new(QosClass::Normal, JobType::Array, 3, 60.0);
        assert!(bare.instantiate(1).tag.is_none());
    }

    #[test]
    fn zipf_user_manifests_chunk_and_replay_deterministically() {
        let templates = [ManifestTemplate::interactive_probe()];
        let a = zipf_user_manifests(9, 500, 30_000, 1.1, &templates);
        let b = zipf_user_manifests(9, 500, 30_000, 1.1, &templates);
        assert_eq!(a, b, "same seed, same trace");
        assert_eq!(a.len(), 3, "30k entries pack into 12k/12k/6k");
        assert_eq!(a[0].entries.len(), MAX_MANIFEST_ENTRIES);
        assert_eq!(a[2].entries.len(), 6_000);
        assert_eq!(a.iter().map(|m| m.entries.len()).sum::<usize>(), 30_000);
        // Heavy tail: rank 1 dominates any deep rank.
        let hits = |user: u32| -> usize {
            a.iter()
                .flat_map(|m| &m.entries)
                .filter(|e| e.user == user)
                .count()
        };
        assert!(hits(1) > hits(400) * 4, "rank 1 should dominate rank 400");
        assert!(a
            .iter()
            .flat_map(|m| &m.entries)
            .all(|e| e.validate().is_ok()));
    }

    #[test]
    fn user_scaling_manifests_cover_every_user_exactly() {
        let users = 25_000u64;
        let ms = user_scaling_manifests(3, users, 1.1);
        let total: usize = ms.iter().map(|m| m.entries.len()).sum();
        assert_eq!(total, users as usize + users as usize / 4);
        assert!(ms.iter().all(|m| m.entries.len() <= MAX_MANIFEST_ENTRIES));
        let distinct: std::collections::BTreeSet<u32> =
            ms.iter().flat_map(|m| &m.entries).map(|e| e.user).collect();
        assert_eq!(distinct.len(), users as usize, "sweep covers every user");
        assert_eq!(distinct.iter().next_back(), Some(&(users as u32)));
        let jobs: u64 = ms.iter().map(|m| m.jobs()).sum();
        assert_eq!(jobs, total as u64, "one job per entry at every level");
        assert!(ms
            .iter()
            .flat_map(|m| &m.entries)
            .any(|e| e.qos == QosClass::Spot));
    }
}
