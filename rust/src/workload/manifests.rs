//! The paper's workload shapes as submission manifests.
//!
//! [`crate::workload::scenarios`] builds `Vec<JobSpec>` for the in-process
//! simulator; this module builds the same shapes as typed
//! [`Manifest`]s so they can be replayed **against a running daemon over
//! TCP** through the public client (`Client::msubmit`) — the live
//! Figure-2 mode in [`crate::experiments::live`] and the
//! `manifest_scaling` bench both draw from here.

use crate::coordinator::manifest::{Manifest, ManifestBuilder, ManifestEntry};
use crate::job::{JobType, QosClass};
use crate::util::rng::Xoshiro256;

/// The interactive Figure-2 burst as a one-entry manifest: exactly what
/// [`crate::workload::interactive_burst`] submits (an *individual* entry
/// expands daemon-side into `tasks` one-task jobs).
pub fn fig2_burst(user: u32, job_type: JobType, tasks: u32, run_secs: f64) -> Manifest {
    ManifestBuilder::new()
        .entry(
            ManifestEntry::new(QosClass::Normal, job_type, tasks, user)
                .with_run_secs(run_secs)
                .with_tag("fig2-live"),
        )
        .build()
}

/// The spot fill as a manifest: `n_jobs` long triple-mode spot entries
/// covering `total_tasks` in aggregate (mirrors
/// [`crate::workload::spot_fill`]).
pub fn spot_fill(user: u32, total_tasks: u32, n_jobs: u32) -> Manifest {
    assert!(n_jobs > 0);
    let per = total_tasks / n_jobs;
    let mut b = ManifestBuilder::new();
    let mut remaining = total_tasks;
    for i in 0..n_jobs {
        let t = if i + 1 == n_jobs { remaining } else { per };
        remaining -= t;
        if t > 0 {
            b = b.entry(
                ManifestEntry::new(QosClass::Spot, JobType::TripleMode, t, user)
                    .with_run_secs(30.0 * 24.0 * 3600.0)
                    .with_tag("spot-fill"),
            );
        }
    }
    b.build()
}

/// A deterministic heterogeneous manifest in the paper's mixture shape:
/// `entries` entries cycling through all three launch types, interactive
/// and spot QoS, and `users` distinct users. Every entry materializes
/// **exactly one job** (individual entries use `tasks=1`), so an
/// `entries`-entry manifest is directly comparable to a homogeneous
/// `count=entries` burst — the `manifest_scaling` bench's equivalence.
pub fn mixed(seed: u64, entries: usize, users: u32) -> Manifest {
    assert!(users >= 1);
    let mut rng = Xoshiro256::new(seed);
    let mut b = ManifestBuilder::new();
    for i in 0..entries {
        let user = 1 + rng.gen_range(0, users as u64) as u32;
        let jt = match i % 3 {
            0 => JobType::Individual,
            1 => JobType::Array,
            _ => JobType::TripleMode,
        };
        let tasks = match jt {
            JobType::Individual => 1,
            _ => 1 + rng.gen_range(0, 8) as u32,
        };
        let entry = if i % 4 == 0 {
            ManifestEntry::new(QosClass::Spot, jt, tasks, 100 + user)
                .with_run_secs(86_400.0)
                .with_tag("mixed-spot")
        } else {
            ManifestEntry::new(QosClass::Normal, jt, tasks, user)
                .with_run_secs(600.0)
                .with_tag("mixed-interactive")
        };
        b = b.entry(entry);
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_burst_matches_scenarios_expansion() {
        let m = fig2_burst(1, JobType::Individual, 608, 600.0);
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.jobs(), 608, "individual expands per task");
        let m = fig2_burst(1, JobType::TripleMode, 4096, 600.0);
        assert_eq!(m.jobs(), 1);
        assert!(m.entries.iter().all(|e| e.validate().is_ok()));
    }

    #[test]
    fn spot_fill_covers_total_like_scenarios() {
        let m = spot_fill(900, 4096, 8);
        assert_eq!(m.entries.len(), 8);
        assert_eq!(m.entries.iter().map(|e| e.tasks).sum::<u32>(), 4096);
        assert!(m.entries.iter().all(|e| e.qos == QosClass::Spot));
        let uneven = spot_fill(900, 100, 3);
        assert_eq!(uneven.entries.iter().map(|e| e.tasks).sum::<u32>(), 100);
    }

    #[test]
    fn mixed_is_deterministic_heterogeneous_and_one_job_per_entry() {
        let a = mixed(7, 1000, 5);
        let b = mixed(7, 1000, 5);
        assert_eq!(a, b, "same seed, same manifest");
        assert_eq!(a.entries.len(), 1000);
        assert_eq!(a.jobs(), 1000, "one job per entry");
        assert!(a.entries.iter().all(|e| e.validate().is_ok()));
        let types: std::collections::BTreeSet<_> =
            a.entries.iter().map(|e| e.job_type.label()).collect();
        assert_eq!(types.len(), 3, "all three launch types present");
        assert!(a.entries.iter().any(|e| e.qos == QosClass::Spot));
        assert!(a.entries.iter().any(|e| e.qos == QosClass::Normal));
        let users: std::collections::BTreeSet<_> = a.entries.iter().map(|e| e.user).collect();
        assert!(users.len() >= 3, "{users:?}");
    }
}
