//! A self-contained mixed-workload simulation (the `spotcloud simulate`
//! subcommand): Poisson interactive arrivals over a spot backlog with the
//! cron agent enabled, reporting utilization and interactive scheduling
//! latency — the paper's headline trade-off, live.

use crate::cluster::{topology, PartitionLayout};
use crate::job::{JobState, QosClass};
use crate::metrics::stats::Summary;
use crate::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use crate::sched::{LogKind, Scheduler, SchedulerConfig};
use crate::sim::{SchedCosts, SimTime};
use crate::workload::gen::{WorkloadGen, WorkloadGenConfig};

/// Outcome of a mixed simulation.
#[derive(Debug, Clone)]
pub struct MixedReport {
    /// Time-averaged cluster utilization (sampled every 60 virtual seconds).
    pub avg_utilization: f64,
    /// Interactive scheduling-latency summary (seconds).
    pub sched_latency: Option<Summary>,
    /// Interactive jobs dispatched / submitted.
    pub interactive_dispatched: usize,
    /// Interactive jobs submitted.
    pub interactive_submitted: usize,
    /// Spot preemptions by the agent.
    pub spot_preemptions: usize,
    /// Whether the spot backlog was enabled.
    pub spot_enabled: bool,
}

impl std::fmt::Display for MixedReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "mixed workload report (spot {}):",
            if self.spot_enabled { "ON" } else { "OFF" }
        )?;
        writeln!(f, "  avg utilization      : {:.1}%", self.avg_utilization * 100.0)?;
        writeln!(
            f,
            "  interactive dispatched: {}/{}",
            self.interactive_dispatched, self.interactive_submitted
        )?;
        if let Some(s) = &self.sched_latency {
            writeln!(
                f,
                "  sched latency         : p50 {:.3}s  p90 {:.3}s  p99 {:.3}s  max {:.3}s",
                s.p50, s.p90, s.p99, s.max
            )?;
        }
        writeln!(f, "  spot preemptions      : {}", self.spot_preemptions)?;
        Ok(())
    }
}

/// Run the simulation. See module docs.
pub fn simulate_mixed(
    seed: u64,
    hours: u64,
    arrivals: usize,
    reserve_nodes: u32,
    spot: bool,
) -> MixedReport {
    let cluster = topology::tx2500();
    let cores_per_node = cluster.cores_per_node();
    let cfg = SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
        .with_user_limit(reserve_nodes.max(1) * cores_per_node)
        .with_phase_seed(seed)
        .with_approach(PreemptApproach::CronAgent {
            mode: PreemptMode::Requeue,
            cfg: CronAgentConfig { reserve_nodes },
        });
    let mut sched = Scheduler::new(cluster, cfg);

    let horizon = SimTime::from_secs(hours.max(1) * 3600);
    let mut gen = WorkloadGen::new(WorkloadGenConfig {
        seed,
        arrival_rate: arrivals as f64 / horizon.as_secs_f64(),
        // Sizes scaled to the TX-2500 reserve.
        sizes: vec![
            (cores_per_node, 0.4),
            (2 * cores_per_node, 0.3),
            (reserve_nodes.max(1) * cores_per_node, 0.3),
        ],
        ..Default::default()
    });

    // Spot backlog: enough long triple-mode jobs to saturate the cap.
    if spot {
        let backlog = gen.spot_backlog(10, 3 * cores_per_node);
        sched.submit_burst(backlog);
    }

    let submissions = gen.interactive_stream(arrivals);
    let mut interactive_ids = Vec::new();
    let mut util_samples = Vec::new();
    let mut next_sample = SimTime::ZERO;

    for sub in &submissions {
        // Advance to the arrival time, sampling utilization on the way.
        while next_sample < sub.at.min(horizon) {
            sched.run_until(next_sample);
            util_samples.push(sched.cluster().utilization());
            next_sample += SimTime::from_secs(60);
        }
        if sub.at >= horizon {
            break;
        }
        sched.run_until(sub.at);
        interactive_ids.extend(sched.submit_burst(sub.specs.clone()));
    }
    while next_sample < horizon {
        sched.run_until(next_sample);
        util_samples.push(sched.cluster().utilization());
        next_sample += SimTime::from_secs(60);
    }
    sched.run_until(horizon);

    let latencies: Vec<f64> = interactive_ids
        .iter()
        .filter_map(|&j| {
            let rec = sched.log().first(j, LogKind::Recognized)?;
            let dis = sched.log().last(j, LogKind::DispatchDone)?;
            Some(dis.saturating_sub(rec).as_secs_f64())
        })
        .collect();
    let dispatched = latencies.len();

    MixedReport {
        avg_utilization: if util_samples.is_empty() {
            0.0
        } else {
            util_samples.iter().sum::<f64>() / util_samples.len() as f64
        },
        sched_latency: Summary::of(&latencies),
        interactive_dispatched: dispatched,
        interactive_submitted: interactive_ids.len(),
        spot_preemptions: sched.log().count(LogKind::CronPreempted),
        spot_enabled: spot,
    }
}

/// Count interactive jobs still pending at the end (diagnostics).
pub fn pending_interactive(sched: &Scheduler) -> usize {
    sched
        .jobs_in_state(JobState::Pending)
        .into_iter()
        .filter(|&id| sched.job(id).map(|j| j.spec.qos) == Some(QosClass::Normal))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spot_raises_utilization() {
        let with_spot = simulate_mixed(7, 2, 40, 5, true);
        let without = simulate_mixed(7, 2, 40, 5, false);
        assert!(
            with_spot.avg_utilization > without.avg_utilization + 0.2,
            "spot {:.2} vs baseline {:.2}",
            with_spot.avg_utilization,
            without.avg_utilization
        );
    }

    #[test]
    fn interactive_latency_stays_low_with_spot() {
        let r = simulate_mixed(7, 2, 40, 5, true);
        let s = r.sched_latency.as_ref().expect("some jobs dispatched");
        // Most interactive work launches fast despite a saturated cluster.
        assert!(s.p50 < 10.0, "p50 {}s", s.p50);
        assert!(r.interactive_dispatched > 0);
    }

    #[test]
    fn report_renders() {
        let r = simulate_mixed(3, 1, 10, 5, true);
        let text = format!("{r}");
        assert!(text.contains("avg utilization"));
    }
}
