//! The paper's experiment scenarios as reusable builders.

use crate::job::{JobSpec, JobType, UserId};
use crate::sim::SimTime;

/// A named scenario (used by the CLI and the experiment harness).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Idle cluster, one interactive submission (baseline measurement).
    Baseline,
    /// Cluster pre-filled with triple-mode spot work, then an interactive
    /// submission that must preempt.
    PreemptFill,
    /// Spot backlog + Poisson interactive arrivals (daemon driver).
    MixedLoad,
}

impl Scenario {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "baseline" => Some(Scenario::Baseline),
            "preempt-fill" => Some(Scenario::PreemptFill),
            "mixed-load" => Some(Scenario::MixedLoad),
            _ => None,
        }
    }
}

/// Build the interactive submission burst for a job type and task count,
/// exactly as the paper submits them:
///
/// * Individual → `tasks` one-task jobs (separate sbatch invocations),
/// * Array / TripleMode → one job of `tasks` tasks.
pub fn interactive_burst(user: UserId, job_type: JobType, tasks: u32) -> Vec<JobSpec> {
    match job_type {
        JobType::Individual => (0..tasks)
            .map(|_| JobSpec::interactive(user, JobType::Individual, 1))
            .collect(),
        _ => vec![JobSpec::interactive(user, job_type, tasks)],
    }
}

/// Build the spot fill: `n_jobs` triple-mode spot jobs covering `total_tasks`
/// tasks in aggregate (the paper fills with one large spot job for Fig 2a–f
/// and "several triple mode spot jobs" for Fig 2g). Spot jobs are long
/// (effectively infinite for the experiment horizon).
pub fn spot_fill(user: UserId, total_tasks: u32, n_jobs: u32) -> Vec<JobSpec> {
    assert!(n_jobs > 0);
    let per = total_tasks / n_jobs;
    let mut out = Vec::with_capacity(n_jobs as usize);
    let mut remaining = total_tasks;
    // One tag allocation shared by the whole fill (tags are Arc<str>).
    let tag: std::sync::Arc<str> = std::sync::Arc::from("spot-fill");
    for i in 0..n_jobs {
        let t = if i + 1 == n_jobs { remaining } else { per };
        remaining -= t;
        if t > 0 {
            out.push(
                JobSpec::spot(user, JobType::TripleMode, t)
                    .with_run_time(SimTime::from_secs(30 * 24 * 3600))
                    .with_tag(std::sync::Arc::clone(&tag)),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn individual_burst_expands() {
        let b = interactive_burst(UserId(1), JobType::Individual, 10);
        assert_eq!(b.len(), 10);
        assert!(b.iter().all(|s| s.tasks == 1));
    }

    #[test]
    fn array_burst_is_single_job() {
        let b = interactive_burst(UserId(1), JobType::Array, 4096);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].tasks, 4096);
    }

    #[test]
    fn spot_fill_covers_total() {
        let fill = spot_fill(UserId(9), 4096, 8);
        assert_eq!(fill.len(), 8);
        assert_eq!(fill.iter().map(|s| s.tasks).sum::<u32>(), 4096);
    }

    #[test]
    fn spot_fill_uneven_split() {
        let fill = spot_fill(UserId(9), 100, 3);
        assert_eq!(fill.iter().map(|s| s.tasks).sum::<u32>(), 100);
    }

    #[test]
    fn scenario_parse() {
        assert_eq!(Scenario::parse("baseline"), Some(Scenario::Baseline));
        assert_eq!(Scenario::parse("nope"), None);
    }
}
