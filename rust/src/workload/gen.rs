//! Stochastic workload generation for the daemon driver and scale tests.
//!
//! Interactive arrivals follow a Poisson process (exponential inter-arrival
//! times); job sizes are drawn from a discrete distribution over the
//! paper's typical interactive sizes; run times are log-normal. Spot
//! backlog jobs are long-running triple-mode jobs. Everything is
//! deterministic given the seed.

use crate::job::{JobSpec, JobType, UserId};
use crate::sim::SimTime;
use crate::util::rng::Xoshiro256;

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct WorkloadGenConfig {
    /// RNG seed.
    pub seed: u64,
    /// Mean interactive arrivals per second.
    pub arrival_rate: f64,
    /// Candidate interactive job sizes (tasks) with weights.
    pub sizes: Vec<(u32, f64)>,
    /// Job-type mix (weights for Individual/Array/TripleMode submissions).
    pub type_weights: [f64; 3],
    /// Log-normal run-time parameters (mu, sigma) in log-seconds.
    pub run_time_lognorm: (f64, f64),
    /// Number of distinct interactive users.
    pub n_users: u32,
}

impl Default for WorkloadGenConfig {
    fn default() -> Self {
        Self {
            seed: 0xC0FFEE,
            arrival_rate: 0.05, // one interactive submission every ~20s
            sizes: vec![(64, 0.4), (128, 0.25), (256, 0.2), (512, 0.1), (1024, 0.05)],
            type_weights: [0.1, 0.3, 0.6], // MIT SuperCloud launches are mostly triple-mode
            run_time_lognorm: (6.0, 1.0),  // median ~400s
            n_users: 16,
        }
    }
}

/// A generated submission.
#[derive(Debug, Clone)]
pub struct Submission {
    /// When the client submits.
    pub at: SimTime,
    /// The burst of specs (individual submissions expand to many specs).
    pub specs: Vec<JobSpec>,
    /// Launch type of the burst.
    pub job_type: JobType,
    /// Total tasks.
    pub tasks: u32,
}

/// The generator.
#[derive(Debug, Clone)]
pub struct WorkloadGen {
    cfg: WorkloadGenConfig,
    rng: Xoshiro256,
    now: f64,
}

impl WorkloadGen {
    /// Create from a config.
    pub fn new(cfg: WorkloadGenConfig) -> Self {
        let rng = Xoshiro256::new(cfg.seed);
        Self { cfg, rng, now: 0.0 }
    }

    fn pick_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.rng.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Next interactive submission.
    pub fn next_interactive(&mut self) -> Submission {
        self.now += self.rng.exponential(self.cfg.arrival_rate);
        let at = SimTime::from_secs_f64(self.now);
        let sizes: Vec<f64> = self.cfg.sizes.iter().map(|&(_, w)| w).collect();
        let size_idx = self.pick_weighted(&sizes);
        let tasks = self.cfg.sizes[size_idx].0;
        let ty = match self.pick_weighted(&self.cfg.type_weights.clone()) {
            0 => JobType::Individual,
            1 => JobType::Array,
            _ => JobType::TripleMode,
        };
        let user = UserId(1 + self.rng.gen_range(0, self.cfg.n_users as u64) as u32);
        let (mu, sigma) = self.cfg.run_time_lognorm;
        let run_secs = self.rng.log_normal(mu, sigma).clamp(10.0, 86_400.0);
        let specs = crate::workload::scenarios::interactive_burst(user, ty, tasks)
            .into_iter()
            .map(|s| s.with_run_time(SimTime::from_secs_f64(run_secs)))
            .collect();
        Submission {
            at,
            specs,
            job_type: ty,
            tasks,
        }
    }

    /// Generate `n` interactive submissions in arrival order.
    pub fn interactive_stream(&mut self, n: usize) -> Vec<Submission> {
        (0..n).map(|_| self.next_interactive()).collect()
    }

    /// A spot backlog of `n` triple-mode jobs of `tasks` each.
    pub fn spot_backlog(&mut self, n: usize, tasks: u32) -> Vec<JobSpec> {
        // One tag allocation for the whole backlog (tags are Arc<str>).
        let tag: std::sync::Arc<str> = std::sync::Arc::from("spot-backlog");
        (0..n)
            .map(|_| {
                let user = UserId(100 + self.rng.gen_range(0, 4) as u32);
                JobSpec::spot(user, JobType::TripleMode, tasks)
                    .with_run_time(SimTime::from_secs(7 * 24 * 3600))
                    .with_tag(std::sync::Arc::clone(&tag))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut g = WorkloadGen::new(WorkloadGenConfig::default());
            g.interactive_stream(20)
                .iter()
                .map(|s| (s.at, s.tasks))
                .collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn arrivals_monotone_and_rate_plausible() {
        let mut g = WorkloadGen::new(WorkloadGenConfig {
            arrival_rate: 1.0,
            ..Default::default()
        });
        let subs = g.interactive_stream(500);
        for w in subs.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        let span = subs.last().unwrap().at.as_secs_f64();
        assert!(
            (300.0..800.0).contains(&span),
            "500 arrivals at rate 1/s spanned {span}s"
        );
    }

    #[test]
    fn sizes_come_from_catalog() {
        let cfg = WorkloadGenConfig::default();
        let catalog: Vec<u32> = cfg.sizes.iter().map(|&(s, _)| s).collect();
        let mut g = WorkloadGen::new(cfg);
        for s in g.interactive_stream(100) {
            assert!(catalog.contains(&s.tasks));
        }
    }

    #[test]
    fn individual_submissions_expand() {
        let mut g = WorkloadGen::new(WorkloadGenConfig {
            type_weights: [1.0, 0.0, 0.0],
            ..Default::default()
        });
        let s = g.next_interactive();
        assert_eq!(s.specs.len() as u32, s.tasks);
    }

    #[test]
    fn spot_backlog_is_spot() {
        let mut g = WorkloadGen::new(WorkloadGenConfig::default());
        let backlog = g.spot_backlog(5, 512);
        assert_eq!(backlog.len(), 5);
        assert!(backlog.iter().all(|s| s.qos == crate::job::QosClass::Spot));
    }
}
