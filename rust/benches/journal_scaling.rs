//! CI bench gate: durability overhead + recovery replay (see
//! `benchkit::journal_scaling`).
//!
//! Times the same per-RPC admission loop with the journal off and under
//! each fsync policy, a concurrent 4-writer loop for the group-commit
//! rows, then times cold `Daemon::recover` at two flat journal sizes and
//! one sharded (2-shard) one, and emits `BENCH_journal.json` (override
//! with `SPOTCLOUD_BENCH_JSON`). The JSON is written **before** the health
//! asserts run, so a regressed run still surfaces its numbers in the CI
//! artifact.
//!
//! Gates:
//! * admission p99 under the default `fsync=interval` policy ≤ 1.5×
//!   journal-off — the WAL sits on the ack path of every admission, so its
//!   steady-state cost is one buffered write per record;
//! * concurrent `fsync=always` + group commit p99 ≤ 3× journal-off at the
//!   same concurrency — full durability batches, it does not serialize;
//! * the sharded 100k-record recovery replays the writer's job ids
//!   identically.
//!
//! `SPOTCLOUD_BENCH_FAST=1` switches to the sub-second smoke configuration.

use spotcloud::benchkit::journal_scaling::{run_journal_scaling, JournalScalingConfig};

fn main() {
    let fast = std::env::var("SPOTCLOUD_BENCH_FAST").as_deref() == Ok("1");
    let cfg = if fast {
        JournalScalingConfig::quick()
    } else {
        JournalScalingConfig::default()
    };
    eprintln!(
        "journal_scaling: {} admissions per policy (off/never/interval/always, {} iters), \
         recovery at {} and {} records",
        cfg.jobs, cfg.iters, cfg.recovery_small, cfg.recovery_large
    );
    let report = run_journal_scaling(&cfg);
    eprintln!("{}", report.summary());

    let path =
        std::env::var("SPOTCLOUD_BENCH_JSON").unwrap_or_else(|_| "BENCH_journal.json".into());
    std::fs::write(&path, report.to_json()).expect("writing bench json");
    println!("wrote {path}");

    // Gates run AFTER the JSON write so a regressed run still surfaces its
    // numbers in the CI artifact.
    assert!(report.all_acked, "a submission was refused: {report:?}");
    assert!(
        report.replay_counts_match,
        "recovery replayed a different record count than was journaled: {report:?}"
    );
    assert!(
        report.interval_vs_off_ratio <= 1.5,
        "journaled admission (fsync=interval) costs {:.2}x journal-off at p99 (gate 1.5x)",
        report.interval_vs_off_ratio,
    );
    assert!(
        report.gc_vs_off_ratio <= 3.0,
        "group-committed fsync=always costs {:.2}x journal-off at concurrent p99 (gate 3x)",
        report.gc_vs_off_ratio,
    );
    assert!(
        report.recovery_sharded_ids_match,
        "sharded recovery did not reproduce the writer's job ids: {report:?}"
    );
}
