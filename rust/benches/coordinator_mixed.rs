//! CI bench gate: the mixed readers+writers+waiters contention scenario
//! against the coordinator core (see `benchkit::coordinator`).
//!
//! Emits `BENCH_coordinator.json` (override with `SPOTCLOUD_BENCH_JSON`)
//! with requests/sec and the p99 virtual scheduling latency — the paper's
//! Figure-2 metric under contention — so the perf trajectory has a
//! machine-readable data point per CI run. Exits non-zero on panic or if
//! the run produced a degenerate result (readers serialized to zero, or
//! waits timing out), which is what the CI job fails on.
//!
//! `SPOTCLOUD_BENCH_FAST=1` switches to the sub-second smoke configuration.

use spotcloud::benchkit::coordinator::{run_mixed_load, MixedLoadConfig};

fn main() {
    let fast = std::env::var("SPOTCLOUD_BENCH_FAST").as_deref() == Ok("1");
    let cfg = if fast {
        MixedLoadConfig::quick()
    } else {
        MixedLoadConfig::default()
    };
    eprintln!(
        "coordinator_mixed: {} readers / {} writers / {} waiters for {:?}",
        cfg.readers, cfg.writers, cfg.waiters, cfg.duration
    );
    let report = run_mixed_load(&cfg);
    eprintln!("{}", report.summary());

    let path = std::env::var("SPOTCLOUD_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_coordinator.json".into());
    std::fs::write(&path, report.to_json()).expect("writing bench json");
    println!("wrote {path}");

    // Gate: the contention run must be healthy, not merely finish.
    assert!(report.read_ops > 0, "readers made no progress");
    assert!(report.write_ops > 0, "writers made no progress");
    assert!(report.wait_ops > 0, "waiters made no progress");
    assert_eq!(
        report.timed_out_waits, 0,
        "interactive launches timed out under contention"
    );
    assert_eq!(
        report.waits_parked, report.waits_resumed,
        "a parked WAIT was lost or woken twice"
    );
    // Readers are snapshot-served: a reader stuck behind a writer burst for
    // a full second would mean the read path re-acquired the write lock.
    assert!(
        report.read_wall.p99() < 1_000_000_000,
        "read p99 {}ns — readers serialized behind writers",
        report.read_wall.p99()
    );
}
