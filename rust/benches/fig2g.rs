//! Bench: regenerate paper fig2g and time it.
mod common;

fn main() {
    common::bench_experiment("fig2g");
}
