//! CI bench gate: connection scaling under the epoll reactor (see
//! `benchkit::connection_scaling`).
//!
//! Emits `BENCH_connections.json` (override with `SPOTCLOUD_BENCH_JSON`):
//! active-request p99 at each idle-connection population (default 100 / 1k
//! / 5k), the reactor wakeup count over a quiet window, and the
//! accept-to-first-byte p99. The JSON is written **before** the health
//! gates run so a regressed run still surfaces its numbers.
//!
//! Gates: p99 at the largest idle population ≤ 2× the smallest, zero
//! request errors, a flat idle wakeup counter, and exactly one reactor
//! thread. `SPOTCLOUD_BENCH_FAST=1` switches to the sub-second smoke
//! configuration. Non-Linux targets print a skip note (the reactor — and
//! so the zero-poll property under test — is Linux-only).

#[cfg(target_os = "linux")]
fn main() {
    use spotcloud::benchkit::connection_scaling::{run_connection_scaling, ConnScalingConfig};

    let fast = std::env::var("SPOTCLOUD_BENCH_FAST").as_deref() == Ok("1");
    let cfg = if fast {
        ConnScalingConfig::quick()
    } else {
        ConnScalingConfig::default()
    };
    eprintln!(
        "connection_scaling: idle levels {:?}, {} active clients x {} requests",
        cfg.idle_levels, cfg.active_clients, cfg.requests_per_client
    );
    let report = run_connection_scaling(&cfg);
    eprintln!("{}", report.summary());

    let path = std::env::var("SPOTCLOUD_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_connections.json".into());
    std::fs::write(&path, report.to_json()).expect("writing bench json");
    println!("wrote {path}");

    // Gates run after the write so the artifact survives a regression.
    assert!(report.levels.len() >= 2, "need at least two idle levels");
    assert_eq!(report.reactor_threads, 1, "connections must ride one reactor thread");
    for l in &report.levels {
        assert_eq!(l.errors, 0, "requests failed at {} idle conns", l.idle_achieved);
        assert!(l.requests > 0, "no requests completed at {} idle conns", l.idle_achieved);
        if l.idle_achieved < l.idle_target {
            // fd-limit short-fall: report it loudly, gate on what ran.
            eprintln!(
                "warning: only {}/{} idle connections established (fd limit?)",
                l.idle_achieved, l.idle_target
            );
        }
        assert!(
            l.reactor_wakeups_while_idle <= 10,
            "{} idle connections woke the reactor {} times — zero-poll broken",
            l.idle_achieved,
            l.reactor_wakeups_while_idle
        );
    }
    let ratio = report.p99_ratio();
    assert!(
        ratio <= 2.0,
        "request p99 degraded {ratio:.2}x from {} to {} idle connections",
        report.levels.first().map(|l| l.idle_achieved).unwrap_or(0),
        report.levels.last().map(|l| l.idle_achieved).unwrap_or(0),
    );
}

#[cfg(not(target_os = "linux"))]
fn main() {
    println!("connection_scaling: skipped (the epoll reactor is Linux-only)");
}
