//! CI bench gate: manifest-admission scaling (see
//! `benchkit::manifest_scaling`).
//!
//! Lands the same N jobs three ways — one N-entry heterogeneous manifest,
//! one homogeneous `count=N` batch, N per-job RPCs — and emits
//! `BENCH_manifest.json` (override with `SPOTCLOUD_BENCH_JSON`). The JSON
//! is written **before** the health asserts run, so a regressed run still
//! surfaces its numbers in the CI artifact.
//!
//! Gates: heterogeneous manifest admission must cost ≤ 1.5× the homogeneous
//! batch per job (the manifest generalizes the batch path; per-entry
//! validation and range bookkeeping must not reintroduce a per-job tax),
//! and the v3 binary manifest codec must parse ≥ 2× the v2 text entry
//! throughput with zero errors (the wire fast path has to pay for itself).
//!
//! `SPOTCLOUD_BENCH_FAST=1` switches to the sub-second smoke configuration.

use spotcloud::benchkit::manifest_scaling::{run_manifest_scaling, ManifestScalingConfig};

fn main() {
    let fast = std::env::var("SPOTCLOUD_BENCH_FAST").as_deref() == Ok("1");
    let cfg = if fast {
        ManifestScalingConfig::quick()
    } else {
        ManifestScalingConfig::default()
    };
    eprintln!(
        "manifest_scaling: {} entries (interactive+spot, 3 launch types, {} users), {} iters",
        cfg.entries, cfg.users, cfg.iters
    );
    let report = run_manifest_scaling(&cfg);
    eprintln!("{}", report.summary());
    eprintln!("{}", report.parse_summary());

    let path =
        std::env::var("SPOTCLOUD_BENCH_JSON").unwrap_or_else(|_| "BENCH_manifest.json".into());
    std::fs::write(&path, report.to_json()).expect("writing bench json");
    println!("wrote {path}");

    // Gates run AFTER the JSON write so a regressed run still surfaces its
    // numbers in the CI artifact.
    assert!(
        report.all_accepted,
        "a manifest entry was rejected: {report:?}"
    );
    assert!(
        report.ids_contiguous,
        "per-entry id ranges were not contiguous/ordered: {report:?}"
    );
    assert!(
        report.manifest_vs_homog_ratio <= 1.5,
        "heterogeneous manifest admission costs {:.2}x the homogeneous batch per job (gate 1.5x)",
        report.manifest_vs_homog_ratio,
    );
    assert_eq!(
        report.v3_parse_errors, 0,
        "v3 binary parse errored or round-tripped unequal: {report:?}"
    );
    assert!(
        report.v3_vs_v2_parse_ratio >= 2.0,
        "v3 binary parse is only {:.2}x v2 text throughput (gate 2x): {}",
        report.v3_vs_v2_parse_ratio,
        report.parse_summary(),
    );
}
