//! Bench: regenerate paper fig2a and time it.
mod common;

fn main() {
    common::bench_experiment("fig2a");
}
