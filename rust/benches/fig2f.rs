//! Bench: regenerate paper Fig 2f (manual preemption panel) and time it.
mod common;

fn main() {
    common::bench_experiment("fig2f");
}
