//! Micro-benchmarks of the L3 scheduler hot paths (wall-clock):
//! submission+dispatch throughput, scheduling-pass cost vs queue depth, and
//! whole-figure simulation speed. These are the §Perf targets in
//! EXPERIMENTS.md.

use spotcloud::benchkit::{BenchConfig, BenchGroup};
use spotcloud::cluster::{topology, PartitionLayout};
use spotcloud::job::{JobSpec, JobType, UserId};
use spotcloud::preempt::{CronAgentConfig, PreemptApproach, PreemptMode};
use spotcloud::sched::{Scheduler, SchedulerConfig};
use spotcloud::sim::{SchedCosts, SimTime};

fn main() {
    let mut g = BenchGroup::new("L3 scheduler hot paths").config(BenchConfig::default());

    // Submission → dispatch, small triple-mode job on an idle cluster.
    g.bench_with_items("submit+dispatch triple-mode (TX-2500)", 1.0, || {
        let mut s = Scheduler::new(
            topology::tx2500(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual),
        );
        let id = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 608));
        s.run_until_dispatched(&[id], SimTime::from_secs(60));
        s
    });

    // A full 4096-job individual burst (the heaviest figure workload).
    g.bench_with_items("individual burst x4096 (TX-Green)", 4096.0, || {
        let mut s = Scheduler::new(
            topology::txgreen_reservation(),
            SchedulerConfig::baseline(SchedCosts::production(), PartitionLayout::Dual),
        );
        let ids = s.submit_burst(
            (0..4096)
                .map(|_| JobSpec::interactive(UserId(1), JobType::Individual, 1))
                .collect(),
        );
        s.run_until_dispatched(&ids, SimTime::from_secs(7200));
        s
    });

    // Scheduling pass cost with a deep pending queue (scoring dominated).
    for depth in [64u32, 512, 2048] {
        g.bench_with_items(
            &format!("pass with {depth}-deep blocked queue"),
            depth as f64,
            move || {
                let mut s = Scheduler::new(
                    topology::tx2500(),
                    SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
                        .with_user_limit(1_000_000),
                );
                // Occupy the cluster so the queue stays pending.
                let big = s.submit(
                    JobSpec::interactive(UserId(2), JobType::Array, 608)
                        .with_run_time(SimTime::from_secs(1_000_000)),
                );
                s.run_until_dispatched(&[big], SimTime::from_secs(60));
                let _q: Vec<_> = (0..depth)
                    .map(|_| s.submit(JobSpec::interactive(UserId(1), JobType::Array, 32)))
                    .collect();
                // Run long enough for several periodic passes over the queue.
                s.run_for(SimTime::from_secs(120));
                s
            },
        );
    }

    // Cron-agent pass on a loaded cluster.
    g.bench("cron agent pass (loaded TX-Green)", || {
        let mut s = Scheduler::new(
            topology::txgreen_reservation(),
            SchedulerConfig::baseline(SchedCosts::dedicated(), PartitionLayout::Dual)
                .with_user_limit(512)
                .with_approach(PreemptApproach::CronAgent {
                    mode: PreemptMode::Requeue,
                    cfg: CronAgentConfig { reserve_nodes: 8 },
                }),
        );
        let ids = s.submit_burst(spotcloud::workload::spot_fill(UserId(9), 3584, 8));
        s.run_until_dispatched(&ids, SimTime::from_secs(600));
        // Interactive takes the reserve; the next cron pass must preempt.
        let j = s.submit(JobSpec::interactive(UserId(1), JobType::TripleMode, 512));
        s.run_until_dispatched(&[j], SimTime::from_secs(60));
        s.run_for(SimTime::from_secs(120));
        s
    });

    g.finish();
}
