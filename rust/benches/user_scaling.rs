//! CI bench gate: user-cardinality scaling (see `benchkit::user_scaling`).
//!
//! Drives Zipf-distributed submissions from 1k → 100k → 1M distinct users
//! through the public `MSUBMIT` admission path and emits
//! `BENCH_users.json` (override with `SPOTCLOUD_BENCH_JSON`). The JSON is
//! written **before** the health asserts run, so a regressed run still
//! surfaces its numbers in the CI artifact.
//!
//! Gate: per-job admission cost at the largest level must stay ≤ 2× the
//! smallest level's — the per-(qos,user) bucket design promises near-flat
//! cost in user count, and this is where that promise is held.
//!
//! `SPOTCLOUD_BENCH_FAST=1` switches to the sub-second smoke configuration.

use spotcloud::benchkit::user_scaling::{run_user_scaling, UserScalingConfig};

fn main() {
    let fast = std::env::var("SPOTCLOUD_BENCH_FAST").as_deref() == Ok("1");
    let cfg = if fast {
        UserScalingConfig::quick()
    } else {
        UserScalingConfig::default()
    };
    eprintln!(
        "user_scaling: levels {:?} distinct users (Zipf s={}), {} iters",
        cfg.levels, cfg.exponent, cfg.iters
    );
    let report = run_user_scaling(&cfg);
    eprintln!("{}", report.summary());

    let path = std::env::var("SPOTCLOUD_BENCH_JSON").unwrap_or_else(|_| "BENCH_users.json".into());
    std::fs::write(&path, report.to_json()).expect("writing bench json");
    println!("wrote {path}");

    // Gates run AFTER the JSON write so a regressed run still surfaces its
    // numbers in the CI artifact.
    assert!(
        report.all_accepted,
        "a user-scaling entry was rejected: {report:?}"
    );
    assert!(
        report.gauges_cover_users,
        "STATS user gauges under-counted a level: {report:?}"
    );
    assert!(
        report.cost_ratio_max_vs_min <= 2.0,
        "per-job admission at {} users costs {:.2}x the {}-user level (gate 2x): {}",
        report.levels.last().map(|l| l.users).unwrap_or(0),
        report.cost_ratio_max_vs_min,
        report.levels.first().map(|l| l.users).unwrap_or(0),
        report.summary(),
    );
}
