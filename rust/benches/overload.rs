//! CI bench gate: interactive latency under a batch flood (see
//! `benchkit::overload`).
//!
//! Times the interactive submit+WAIT loop over real TCP against an idle
//! daemon and again under a sustained batch flood from a rate-limited
//! user, and emits `BENCH_overload.json` (override with
//! `SPOTCLOUD_BENCH_JSON`). The JSON is written **before** the gates run,
//! so a regressed run still surfaces its numbers in the CI artifact.
//!
//! Gates:
//! * flooded interactive WAIT p99 ≤ 3× unflooded — a batch flood cannot
//!   buy batch throughput with interactive latency;
//! * zero interactive sheds — load shedding refuses the flood, never the
//!   interactive user inside its own budget;
//! * shed batch requests > 0 — the flood was actually refused with the
//!   typed `overloaded`, not silently absorbed;
//! * the daemon reported `shedding` over HEALTH while the flood was hot
//!   and recovered to `healthy` once it stopped.
//!
//! `SPOTCLOUD_BENCH_FAST=1` switches to the sub-second smoke configuration.

use spotcloud::benchkit::overload::{run_overload, OverloadBenchConfig};

fn main() {
    let fast = std::env::var("SPOTCLOUD_BENCH_FAST").as_deref() == Ok("1");
    let cfg = if fast {
        OverloadBenchConfig::quick()
    } else {
        OverloadBenchConfig::default()
    };
    eprintln!(
        "overload: {} interactive ops per phase, {} flood conns × count={} \
         (target {} jobs), user bucket {}/s burst {}",
        cfg.interactive_ops,
        cfg.flood_conns,
        cfg.flood_count_per_req,
        cfg.flood_target_jobs,
        cfg.user_rate,
        cfg.user_burst,
    );
    let report = run_overload(&cfg);
    eprintln!("{}", report.summary());

    let path =
        std::env::var("SPOTCLOUD_BENCH_JSON").unwrap_or_else(|_| "BENCH_overload.json".into());
    std::fs::write(&path, report.to_json()).expect("writing bench json");
    println!("wrote {path}");

    // Gates run AFTER the JSON write so a regressed run still surfaces its
    // numbers in the CI artifact.
    assert_eq!(
        report.interactive_sheds, 0,
        "the interactive user was shed: {report:?}"
    );
    assert!(
        report.shed_batch_requests > 0,
        "the batch flood was never shed: {report:?}"
    );
    assert!(
        report.flooded_vs_unflooded_ratio <= 3.0,
        "flooded interactive WAIT p99 is {:.2}x unflooded (gate 3x): {report:?}",
        report.flooded_vs_unflooded_ratio,
    );
    assert!(
        report.observed_shedding,
        "daemon never reported `shedding` under the flood: {report:?}"
    );
    assert!(
        report.recovered_healthy,
        "daemon never recovered to `healthy` after the flood: {report:?}"
    );
}
