//! Bench: regenerate paper Fig 2d and Fig 2e (REQUEUE vs CANCEL panels)
//! and time them.
mod common;

fn main() {
    common::bench_experiment("fig2d");
    common::bench_experiment("fig2e");
}
