//! Micro-benchmarks of the simulation substrates: event queue throughput,
//! histogram recording, RNG, and the DES engine loop.

use spotcloud::benchkit::{BenchConfig, BenchGroup};
use spotcloud::metrics::LogHistogram;
use spotcloud::sim::{Engine, EventQueue, SimTime};
use spotcloud::util::rng::Xoshiro256;

fn main() {
    let mut g = BenchGroup::new("simulation substrates").config(BenchConfig::default());

    g.bench_with_items("event queue push+pop x10k", 10_000.0, || {
        let mut q = EventQueue::new();
        let mut rng = Xoshiro256::new(1);
        for i in 0..10_000u64 {
            q.push(SimTime(rng.gen_range(0, 1_000_000_000)), i);
        }
        let mut sum = 0u64;
        while let Some((_, v)) = q.pop() {
            sum += v;
        }
        sum
    });

    g.bench_with_items("DES engine self-scheduling x10k", 10_000.0, || {
        let mut eng: Engine<u32> = Engine::new();
        eng.schedule_at(SimTime(1), 0);
        eng.run_to_completion(|eng, _, n| {
            if n < 10_000 {
                eng.schedule_in(SimTime(1_000), n + 1);
            }
        });
        eng.processed()
    });

    g.bench_with_items("histogram record x100k", 100_000.0, || {
        let mut h = LogHistogram::new();
        let mut rng = Xoshiro256::new(2);
        for _ in 0..100_000 {
            h.record(rng.gen_range(1, 10_000_000_000));
        }
        h.p99()
    });

    g.bench_with_items("xoshiro256** u64 x1M", 1_000_000.0, || {
        let mut rng = Xoshiro256::new(3);
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        acc
    });

    g.finish();
}
