//! Bench: regenerate paper ablations and time it.
mod common;

fn main() {
    common::bench_experiment("ablations");
}
