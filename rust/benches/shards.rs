//! CI bench gate: the sharded front door + partition-sharded scheduler
//! (see `benchkit::shard_scaling`).
//!
//! Emits `BENCH_shards.json` (override with `SPOTCLOUD_BENCH_JSON`): per
//! shard count {1, 2, 4}, the submit-storm throughput and p99, the worst
//! per-shard idle wakeup count over a 50k-connection quiet window, and
//! the effective reactor/scheduler shard counts. The JSON is written
//! **before** the health gates run so a regressed run still surfaces its
//! numbers.
//!
//! Gates: 2-shard submit throughput ≥ 1.6× the 1-shard figure, 2-shard
//! p99 no worse than single-shard (1.25× noise allowance), zero request
//! errors, and a flat idle wakeup counter on **every** shard.
//! `SPOTCLOUD_BENCH_FAST=1` switches to the sub-second smoke
//! configuration. Non-Linux targets print a skip note (`SO_REUSEPORT`
//! sharding — and so the property under test — is Linux-only).

/// Raise `RLIMIT_NOFILE` toward its hard limit: the full sweep holds 50k
/// idle sockets (plus their server-side peers in the same process), far
/// past the common 1024 soft default. Best-effort — the scenario reports
/// `idle_achieved` and the gates note a short-fall rather than failing it.
#[cfg(target_os = "linux")]
fn raise_fd_limit() {
    #[repr(C)]
    struct Rlimit {
        rlim_cur: u64,
        rlim_max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    let mut lim = Rlimit { rlim_cur: 0, rlim_max: 0 };
    // SAFETY: plain syscalls on a properly sized, initialized struct.
    unsafe {
        if getrlimit(RLIMIT_NOFILE, &mut lim) == 0 && lim.rlim_cur < lim.rlim_max {
            let want = Rlimit { rlim_cur: lim.rlim_max, rlim_max: lim.rlim_max };
            if setrlimit(RLIMIT_NOFILE, &want) == 0 {
                eprintln!("raised RLIMIT_NOFILE {} -> {}", lim.rlim_cur, lim.rlim_max);
            }
        }
    }
}

#[cfg(target_os = "linux")]
fn main() {
    use spotcloud::benchkit::shard_scaling::{run_shard_scaling, ShardScalingConfig};

    raise_fd_limit();
    let fast = std::env::var("SPOTCLOUD_BENCH_FAST").as_deref() == Ok("1");
    let cfg = if fast {
        ShardScalingConfig::quick()
    } else {
        ShardScalingConfig::default()
    };
    eprintln!(
        "shards: sweep {:?}, {} idle conns, {} submitters x {} submits",
        cfg.shard_counts, cfg.idle_conns, cfg.submitters, cfg.submits_per_thread
    );
    let report = run_shard_scaling(&cfg);
    eprintln!("{}", report.summary());

    let path =
        std::env::var("SPOTCLOUD_BENCH_JSON").unwrap_or_else(|_| "BENCH_shards.json".into());
    std::fs::write(&path, report.to_json()).expect("writing bench json");
    println!("wrote {path}");

    // Gates run after the write so the artifact survives a regression.
    assert!(report.levels.len() >= 2, "need the 1- and 2-shard levels");
    for l in &report.levels {
        assert_eq!(l.errors, 0, "submissions failed at {} shard(s)", l.shards);
        assert!(l.submits > 0, "no submissions completed at {} shard(s)", l.shards);
        assert_eq!(
            l.reactor_shards, l.shards,
            "server ran {} reactor shard(s), configured {}",
            l.reactor_shards, l.shards
        );
        if l.idle_achieved < l.idle_target {
            // fd-limit short-fall: report it loudly, gate on what ran.
            eprintln!(
                "warning: only {}/{} idle connections established (fd limit?)",
                l.idle_achieved, l.idle_target
            );
        }
        assert!(
            l.idle_wakeups_max_per_shard <= 10,
            "{} idle connections woke a shard {} times at {} shard(s) — \
             per-shard zero-poll broken",
            l.idle_achieved,
            l.idle_wakeups_max_per_shard,
            l.shards
        );
    }
    let throughput = report.throughput_ratio_1_to_2();
    assert!(
        throughput >= 1.6,
        "2-shard submit throughput only {throughput:.2}x the 1-shard figure (gate: >= 1.6x)"
    );
    // "No worse" with a noise allowance: the storm's tail is a handful of
    // microseconds, where scheduler-jitter noise alone moves double digits.
    let p99 = report.p99_ratio_1_to_2();
    assert!(
        p99 <= 1.25,
        "2-shard submit p99 degraded {p99:.2}x vs single-shard (gate: <= 1.25x)"
    );
}

#[cfg(not(target_os = "linux"))]
fn main() {
    println!("shards: skipped (SO_REUSEPORT reactor sharding is Linux-only)");
}
