//! Micro-benchmarks of the scheduling decision step: XLA-compiled kernel vs
//! native Rust fallback, across batch sizes. The crossover tells the
//! scheduler when offloading pays (see EXPERIMENTS.md §Perf).

use spotcloud::benchkit::{BenchConfig, BenchGroup};
use spotcloud::runtime::{fallback, SchedAccel};
use spotcloud::sched::priority::{JobFactors, PriorityScorer, N_FACTORS, WEIGHTS};
use spotcloud::util::rng::Xoshiro256;

fn random_factors(n: usize, seed: u64) -> Vec<JobFactors> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| {
            let mut f = [0.0f32; N_FACTORS];
            for x in f.iter_mut() {
                *x = rng.uniform(0.0, 10.0) as f32;
            }
            JobFactors(f)
        })
        .collect()
}

fn main() {
    let accel = SchedAccel::load_default();
    if accel.is_none() {
        println!("artifacts not built (run `make artifacts`); benchmarking fallback only");
    }
    let mut g = BenchGroup::new("decision step: XLA accel vs native fallback")
        .config(BenchConfig::default());

    for n in [64usize, 256, 1024] {
        let factors = random_factors(n, 42);
        let f2 = factors.clone();
        g.bench_with_items(&format!("native scores n={n}"), n as f64, move || {
            fallback::priority_scores(&f2, &WEIGHTS)
        });
        if let Some(a) = &accel {
            let f3 = factors.clone();
            g.bench_with_items(&format!("xla scores n={n}"), n as f64, || a.scores(&f3));
        }
    }

    // The full fused decision step (scores + preempt mask + fit counts).
    if let Some(a) = &accel {
        let factors = random_factors(1024, 7);
        let mut rng = Xoshiro256::new(9);
        let spot: Vec<f32> = (0..1024).map(|_| rng.gen_range(0, 512) as f32).collect();
        let free: Vec<f32> = (0..1024).map(|_| rng.gen_range(0, 65) as f32).collect();
        let reqs: Vec<f32> = (0..1024).map(|_| rng.gen_range(1, 64) as f32).collect();
        let (s2, f2, r2) = (spot.clone(), free.clone(), reqs.clone());
        g.bench("xla full sched_step (1024 jobs, 1024 spots, 1024 nodes)", move || {
            a.sched_step(&factors, &s2, 100_000.0, &f2, &r2).expect("step")
        });
        g.bench("native full step equivalent", move || {
            let factors = random_factors(1024, 7);
            let scores = fallback::priority_scores(&factors, &WEIGHTS);
            let mask = fallback::select_victims(&spot, 100_000.0);
            let counts = fallback::fit_counts(&free, &reqs);
            (scores, mask, counts)
        });
    }

    g.finish();
}
