//! Bench: regenerate paper table1 and time it.
mod common;

fn main() {
    common::bench_experiment("table1");
}
