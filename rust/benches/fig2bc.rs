//! Bench: regenerate paper Fig 2b and Fig 2c (production auto-preemption
//! panels at 2048 and 4096 cores) and time them.
mod common;

fn main() {
    common::bench_experiment("fig2b");
    common::bench_experiment("fig2c");
}
