//! Shared bench plumbing: run a paper experiment, print its report (the
//! figure's rows), and benchmark the regeneration wall time.

use spotcloud::benchkit::{BenchConfig, BenchGroup};

/// Run experiment `id`: print the figure once (with shape checks), then
/// benchmark regeneration time.
pub fn bench_experiment(id: &str) {
    let report = spotcloud::experiments::run_by_id(id, 1).expect("known experiment");
    println!("{}", report.render());
    assert!(report.check(), "paper-shape checks failed for {id}");

    let mut g = BenchGroup::new(&format!("{id} regeneration")).config(BenchConfig::heavy());
    let mut seed = 0u64;
    g.bench(&format!("{id}::run"), move || {
        seed += 1;
        spotcloud::experiments::run_by_id(id, seed).expect("known experiment")
    });
    g.finish();
}
