//! CI bench gate: scheduler burst-scaling scenario (see
//! `benchkit::sched_scaling`).
//!
//! Emits `BENCH_sched_scaling.json` (override with
//! `SPOTCLOUD_BENCH_JSON`) with the wall-clock scheduling cost per job for
//! individual bursts of growing size, plus the mixed-preemption scenario
//! and snapshot capture costs. The JSON is written **before** the health
//! asserts run, so a regressed run still surfaces its numbers in the CI
//! artifact.
//!
//! Gate: near-linear burst scaling — per-job cost at the largest size must
//! stay within 2× of the smallest (quadratic hot paths showed up as 30–100×
//! here before the incremental queue layer).
//!
//! `SPOTCLOUD_BENCH_FAST=1` switches to the sub-second smoke configuration.

use spotcloud::benchkit::sched_scaling::{run_sched_scaling, ScalingConfig};

fn main() {
    let fast = std::env::var("SPOTCLOUD_BENCH_FAST").as_deref() == Ok("1");
    let cfg = if fast {
        ScalingConfig::quick()
    } else {
        ScalingConfig::default()
    };
    eprintln!(
        "sched_scaling: individual bursts of {:?}, mixed preemption with {} jobs",
        cfg.sizes, cfg.mixed_jobs
    );
    let report = run_sched_scaling(&cfg);
    eprintln!("{}", report.summary());

    let path = std::env::var("SPOTCLOUD_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_sched_scaling.json".into());
    std::fs::write(&path, report.to_json()).expect("writing bench json");
    println!("wrote {path}");

    // Gates run AFTER the JSON write so a regressed run still surfaces its
    // numbers in the CI artifact.
    // Every scenario must have fully dispatched within its horizon.
    assert!(
        report.sizes.iter().all(|s| s.completed),
        "a burst failed to dispatch within its horizon: {:?}",
        report.sizes,
    );
    assert!(report.mixed.completed, "mixed scenario stalled: {:?}", report.mixed);
    // Gate: dispatch cost per job stays flat across three orders of
    // magnitude of burst size.
    assert!(
        report.per_job_ratio <= 2.0,
        "per-job scheduling cost is not flat: {:.2}x from {} to {} jobs",
        report.per_job_ratio,
        report.sizes.first().map(|s| s.jobs).unwrap_or(0),
        report.sizes.last().map(|s| s.jobs).unwrap_or(0),
    );
    // The preemption path must have been exercised, not skipped.
    assert!(report.mixed.preemptions > 0, "mixed scenario never preempted");
    // Delta capture must beat the cold full-table capture decisively on a
    // large table (it re-uses every unchanged JobView allocation).
    assert!(
        report.capture_delta_us < report.capture_full_us,
        "delta capture ({:.0}us) is not cheaper than full capture ({:.0}us)",
        report.capture_delta_us,
        report.capture_full_us,
    );
}
